//! GA loop-offload search — the prior-work baseline ([32], [33]).
//!
//! The paper's earlier method: narrow to parallelizable loop statements,
//! encode each as a gene (1 = GPU, 0 = CPU), then evolve the population
//! with repeated *measured* performance verification. We reproduce it
//! faithfully:
//!
//! * genes come from `analysis::Analysis::parallel_loops`,
//! * fitness is measured wall-clock of the application in the verification
//!   environment (bulk executor = simulated GPU; see `interp`),
//! * roulette selection on inverse time, single-point crossover, per-bit
//!   mutation, elitism of 1,
//! * a gene→time cache avoids re-measuring identical patterns (FPGA-style
//!   economy; also what makes the "GA takes hours" point fair — the cost
//!   is measured trials, not bookkeeping).
//!
//! `History` records the best speedup per generation — exactly the series
//! Fig. 4 plots.

pub mod rng;

use std::collections::HashMap;
use std::time::Duration;

use anyhow::Result;

use rng::Rng;

/// GA tuning knobs (defaults follow [33]'s small-population regime).
#[derive(Debug, Clone)]
pub struct GaConfig {
    /// Individuals per generation.
    pub population: usize,
    /// Generations to run.
    pub generations: usize,
    /// Probability of two-point crossover per offspring.
    pub crossover_rate: f64,
    /// Per-gene mutation probability.
    pub mutation_rate: f64,
    /// Individuals preserved unchanged each generation.
    pub elite: usize,
    /// PRNG seed (the search is fully deterministic).
    pub seed: u64,
}

impl Default for GaConfig {
    fn default() -> Self {
        GaConfig {
            population: 12,
            generations: 10,
            crossover_rate: 0.9,
            mutation_rate: 0.05,
            elite: 1,
            seed: 20200207,
        }
    }
}

/// Per-generation record (the Fig. 4 series).
#[derive(Debug, Clone)]
pub struct GenStats {
    /// Generation index (0-based).
    pub generation: usize,
    /// Best-so-far speedup vs the all-CPU baseline.
    pub best_speedup: f64,
    /// Mean speedup of this generation's evaluated individuals.
    pub mean_speedup: f64,
    /// Cumulative measured trials (cache misses) so far.
    pub trials: usize,
}

/// GA outcome.
#[derive(Debug, Clone)]
pub struct GaResult {
    /// Best on/off pattern found.
    pub best_gene: Vec<bool>,
    /// Measured time of the best gene.
    pub best_time: Duration,
    /// All-CPU baseline time.
    pub baseline_time: Duration,
    /// Per-generation series (Fig. 4).
    pub history: Vec<GenStats>,
    /// Total measured trials (= verification-environment runs).
    pub trials: usize,
}

impl GaResult {
    /// Speedup of the best gene over the baseline.
    pub fn best_speedup(&self) -> f64 {
        self.baseline_time.as_secs_f64() / self.best_time.as_secs_f64().max(1e-12)
    }
}

/// Fitness oracle: measure the application with the given loop-offload
/// pattern. Must be deterministic enough for comparison (median-of-k
/// inside is fine).
pub trait FitnessFn {
    fn measure(&mut self, gene: &[bool]) -> Result<Duration>;
}

impl<F: FnMut(&[bool]) -> Result<Duration>> FitnessFn for F {
    fn measure(&mut self, gene: &[bool]) -> Result<Duration> {
        self(gene)
    }
}

/// Run the GA over `n_genes` parallelizable loops.
pub fn run<F: FitnessFn>(n_genes: usize, cfg: &GaConfig, fitness: &mut F) -> Result<GaResult> {
    let mut rng = Rng::new(cfg.seed);
    let mut cache: HashMap<Vec<bool>, Duration> = HashMap::new();
    let mut trials = 0usize;

    // Baseline: all-CPU (all genes off).
    let baseline = {
        let gene = vec![false; n_genes];
        let t = fitness.measure(&gene)?;
        trials += 1;
        cache.insert(gene, t);
        t
    };

    if n_genes == 0 {
        return Ok(GaResult {
            best_gene: vec![],
            best_time: baseline,
            baseline_time: baseline,
            history: vec![],
            trials,
        });
    }

    // Initial population: random genes, half-density.
    let mut pop: Vec<Vec<bool>> = (0..cfg.population)
        .map(|_| (0..n_genes).map(|_| rng.bool_with(0.5)).collect())
        .collect();

    let mut best_gene = vec![false; n_genes];
    let mut best_time = baseline;
    let mut history = Vec::with_capacity(cfg.generations);

    for generation in 0..cfg.generations {
        // Evaluate (with caching — identical patterns are not re-measured).
        let mut times = Vec::with_capacity(pop.len());
        for gene in &pop {
            let t = match cache.get(gene) {
                Some(t) => *t,
                None => {
                    let t = fitness.measure(gene)?;
                    trials += 1;
                    cache.insert(gene.clone(), t);
                    t
                }
            };
            if t < best_time {
                best_time = t;
                best_gene = gene.clone();
            }
            times.push(t);
        }

        let mean_speedup = times
            .iter()
            .map(|t| baseline.as_secs_f64() / t.as_secs_f64().max(1e-12))
            .sum::<f64>()
            / times.len() as f64;
        history.push(GenStats {
            generation,
            best_speedup: baseline.as_secs_f64() / best_time.as_secs_f64().max(1e-12),
            mean_speedup,
            trials,
        });

        if generation + 1 == cfg.generations {
            break;
        }

        // Roulette selection on inverse time.
        let weights: Vec<f64> =
            times.iter().map(|t| 1.0 / t.as_secs_f64().max(1e-9)).collect();
        let total: f64 = weights.iter().sum();
        let select = |rng: &mut Rng| -> &Vec<bool> {
            let mut target = rng.next_f64() * total;
            for (i, w) in weights.iter().enumerate() {
                target -= w;
                if target <= 0.0 {
                    return &pop[i];
                }
            }
            pop.last().unwrap()
        };

        // Next generation: elites + crossover/mutation offspring.
        let mut order: Vec<usize> = (0..pop.len()).collect();
        order.sort_by_key(|&i| times[i]);
        let mut next: Vec<Vec<bool>> =
            order.iter().take(cfg.elite).map(|&i| pop[i].clone()).collect();

        while next.len() < cfg.population {
            let a = select(&mut rng).clone();
            let b = select(&mut rng).clone();
            let mut child = if rng.bool_with(cfg.crossover_rate) && n_genes > 1 {
                let cut = 1 + rng.below(n_genes - 1);
                let mut c = a[..cut].to_vec();
                c.extend_from_slice(&b[cut..]);
                c
            } else {
                a
            };
            for bit in child.iter_mut() {
                if rng.bool_with(cfg.mutation_rate) {
                    *bit = !*bit;
                }
            }
            next.push(child);
        }
        pop = next;
    }

    Ok(GaResult { best_gene, best_time, baseline_time: baseline, history, trials })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// Synthetic fitness landscape: loops 0 and 2 help (big), loop 1 hurts
    /// (transfer-dominated), loop 3 is neutral-ish.
    fn synthetic(gene: &[bool]) -> Result<Duration> {
        let mut t = 1000.0f64; // ms
        if gene[0] {
            t -= 400.0;
        }
        if gene[1] {
            t += 150.0;
        }
        if gene[2] {
            t -= 300.0;
        }
        if gene[3] {
            t -= 5.0;
        }
        Ok(Duration::from_secs_f64(t.max(1.0) / 1000.0))
    }

    #[test]
    fn ga_finds_the_optimum_on_synthetic_landscape() {
        let cfg = GaConfig { population: 10, generations: 12, ..Default::default() };
        let mut f = synthetic;
        let r = run(4, &cfg, &mut f).unwrap();
        assert!(r.best_gene[0], "gene0 should be offloaded");
        assert!(!r.best_gene[1], "gene1 hurts and should be off");
        assert!(r.best_gene[2], "gene2 should be offloaded");
        assert!(r.best_speedup() > 3.0, "speedup {}", r.best_speedup());
    }

    #[test]
    fn history_is_monotone_best() {
        let cfg = GaConfig { population: 8, generations: 8, ..Default::default() };
        let mut f = synthetic;
        let r = run(4, &cfg, &mut f).unwrap();
        assert_eq!(r.history.len(), 8);
        for w in r.history.windows(2) {
            assert!(w[1].best_speedup >= w[0].best_speedup - 1e-9);
        }
    }

    #[test]
    fn deterministic_with_same_seed() {
        let cfg = GaConfig::default();
        let mut f1 = synthetic;
        let mut f2 = synthetic;
        let a = run(4, &cfg, &mut f1).unwrap();
        let b = run(4, &cfg, &mut f2).unwrap();
        assert_eq!(a.best_gene, b.best_gene);
        assert_eq!(a.trials, b.trials);
    }

    #[test]
    fn cache_avoids_redundant_trials() {
        let cfg = GaConfig { population: 12, generations: 10, ..Default::default() };
        let mut f = synthetic;
        let r = run(4, &cfg, &mut f).unwrap();
        // 16 possible genomes; trials cannot exceed that.
        assert!(r.trials <= 16 + 1, "trials {}", r.trials);
    }

    #[test]
    fn zero_genes_short_circuits() {
        let mut calls = 0usize;
        let mut f = |_: &[bool]| {
            calls += 1;
            Ok(Duration::from_millis(10))
        };
        let r = run(0, &GaConfig::default(), &mut f).unwrap();
        assert_eq!(calls, 1); // baseline only
        assert!(r.best_gene.is_empty());
        assert!((r.best_speedup() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn elite_preserved() {
        // With heavy mutation, elitism still keeps the best ever found.
        let cfg = GaConfig {
            population: 8,
            generations: 15,
            mutation_rate: 0.4,
            ..Default::default()
        };
        let mut f = synthetic;
        let r = run(4, &cfg, &mut f).unwrap();
        let last = r.history.last().unwrap();
        assert!(last.best_speedup >= 3.0);
    }
}
