//! Deterministic PRNG (xorshift64*) for the GA.
//!
//! The verification environment must be reproducible run-to-run, so the GA
//! takes an explicit seed; no external randomness crates are used.

/// xorshift64* — tiny, fast, and plenty for GA sampling.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded generator (the all-zero fixed point is avoided).
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Rng { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    /// Next raw 64-bit sample.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 8];
        for _ in 0..8000 {
            counts[r.below(8)] += 1;
        }
        for c in counts {
            assert!((600..1400).contains(&c), "{counts:?}");
        }
    }
}
