//! The `fbo-fleet-v1` wire protocol: versioned, length-prefixed,
//! canonical-JSON frames.
//!
//! Every frame is encoded as
//!
//! ```text
//! <payload byte length, ASCII decimal>\n
//! <payload: one-line canonical JSON>\n
//! ```
//!
//! The payload is [`crate::patterndb::json::to_string_compact`] output —
//! sorted keys, no whitespace — so a frame round-trips byte-identically
//! and the golden fixture under `tests/fixtures/` pins the schema. The
//! codec is transport-agnostic: the same [`read_frame`] / [`write_frame`]
//! pair runs over a TCP stream and over a spawned child's stdio pipe.
//!
//! Conversation shape (scheduler = client, worker = server):
//!
//! | frame            | direction           | meaning                                      |
//! |------------------|---------------------|----------------------------------------------|
//! | `hello`          | worker -> scheduler | first frame: protocol version + capabilities |
//! | `measure-batch`  | scheduler -> worker | measure these specs, reply under the same id |
//! | `measure-result` | worker -> scheduler | index-aligned outcomes of batch `id`         |
//! | `heartbeat`      | either              | liveness probe; the peer echoes the seq      |
//! | `drain`          | scheduler -> worker | finish in-flight work, reply `bye`, close    |
//! | `bye`            | either              | final frame before closing the transport     |
//!
//! A version mismatch is detected on the `hello` frame and rejected by
//! the registry before any work is dealt; a malformed frame is a
//! connection-fatal error on whichever side reads it (never a crash).

use std::io::{BufRead, Write};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::report_json::{
    measurement_from_json, measurement_to_json, plan_from_json, plan_to_json, traffic_from_json,
    traffic_to_json,
};
use crate::coordinator::verify::{MeasuredPattern, PatternSpec, ResultProbe};
use crate::coordinator::VerifyConfig;
use crate::patterndb::json::{self, Json};
use crate::transform::PlannedReplacement;

/// Protocol identifier carried by every [`Frame::Hello`]; bump on any
/// incompatible schema change.
pub const PROTOCOL: &str = "fbo-fleet-v1";

/// Upper bound on one frame's payload, guarding the reader against a
/// garbage length prefix allocating unbounded memory.
pub const MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

/// What a worker can measure, announced in its [`Frame::Hello`]. The
/// scheduler only deals a pattern to a worker whose capabilities cover
/// every enabled block of the pattern.
#[derive(Debug, Clone, PartialEq)]
pub struct Capabilities {
    /// Worker can measure GPU-library replacements (PJRT artifacts).
    pub gpu: bool,
    /// Worker can measure FPGA IP-core replacements.
    pub fpga: bool,
    /// Device model string (informational; surfaces in stats and logs).
    pub device: String,
    /// Patterns the worker measures concurrently (its engine plus
    /// measure-only siblings).
    pub max_inflight: usize,
}

impl Default for Capabilities {
    fn default() -> Self {
        Capabilities { gpu: true, fpga: true, device: "pjrt-cpu".to_string(), max_inflight: 1 }
    }
}

/// One self-contained measurement batch: everything a worker needs to
/// re-create the [`crate::coordinator::VerifyContext`] and run
/// [`crate::coordinator::verify::measure_spec`] — the library-linked
/// program source, the entry point, the reconciled block list, the
/// measurement settings, and the pattern specs to measure.
#[derive(Debug, Clone)]
pub struct WireBatch {
    /// Printed form of the library-linked program (re-parsed remotely).
    pub source: String,
    /// Entry-point function name.
    pub entry: String,
    /// Accepted replacement plans, in block order.
    pub blocks: Vec<PlannedReplacement>,
    /// Measurement settings (reps, warmup, fuel, tolerance).
    pub cfg: VerifyConfig,
    /// The patterns to measure, in batch order.
    pub specs: Vec<PatternSpec>,
}

/// One pattern's outcome inside a [`Frame::MeasureResult`], index-aligned
/// with the batch's specs.
#[derive(Debug, Clone)]
pub enum WireOutcome {
    /// The pattern measured successfully.
    Ok(MeasuredPattern),
    /// The measurement failed on the worker.
    Err {
        /// Top-level error text, mirroring what a local executor's error
        /// would display — the resolved pattern label stays identical to
        /// the serial executor's.
        message: String,
        /// Full error context chain, for logs only.
        detail: String,
    },
}

/// One protocol frame. See the module docs for the conversation shape.
#[derive(Debug, Clone)]
pub enum Frame {
    /// First frame a worker sends on any transport: its protocol version
    /// and capabilities.
    Hello {
        /// Protocol identifier; must equal [`PROTOCOL`].
        protocol: String,
        /// What this worker can measure.
        caps: Capabilities,
    },
    /// Scheduler -> worker: measure `batch`, reply with a
    /// [`Frame::MeasureResult`] carrying the same id.
    MeasureBatch {
        /// Correlation id echoed by the result frame.
        id: u64,
        /// The self-contained measurement batch.
        batch: WireBatch,
    },
    /// Worker -> scheduler: outcomes of batch `id`, index-aligned with
    /// the batch's specs.
    MeasureResult {
        /// Correlation id of the batch these results answer.
        id: u64,
        /// One outcome per spec, in spec order.
        results: Vec<WireOutcome>,
    },
    /// Liveness probe; the receiving side echoes the same seq back.
    Heartbeat {
        /// Probe sequence number, echoed verbatim.
        seq: u64,
    },
    /// Scheduler -> worker: finish in-flight work, reply [`Frame::Bye`],
    /// then close — the fleet mirror of the pool's drain-then-stop.
    Drain,
    /// Final frame either side sends before closing the transport.
    Bye,
}

impl Frame {
    /// Canonical frame name — the JSON `"frame"` discriminator.
    pub fn name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "hello",
            Frame::MeasureBatch { .. } => "measure-batch",
            Frame::MeasureResult { .. } => "measure-result",
            Frame::Heartbeat { .. } => "heartbeat",
            Frame::Drain => "drain",
            Frame::Bye => "bye",
        }
    }

    /// Serialize to the canonical JSON payload value.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![("frame", Json::str(self.name()))];
        match self {
            Frame::Hello { protocol, caps } => {
                pairs.push(("protocol", Json::str(protocol)));
                pairs.push(("gpu", Json::Bool(caps.gpu)));
                pairs.push(("fpga", Json::Bool(caps.fpga)));
                pairs.push(("device", Json::str(&caps.device)));
                pairs.push(("max_inflight", Json::num(caps.max_inflight as f64)));
            }
            Frame::MeasureBatch { id, batch } => {
                pairs.push(("id", Json::num(*id as f64)));
                pairs.push(("source", Json::str(&batch.source)));
                pairs.push(("entry", Json::str(&batch.entry)));
                pairs.push(("blocks", Json::Arr(batch.blocks.iter().map(plan_to_json).collect())));
                pairs.push(("reps", Json::num(batch.cfg.reps as f64)));
                pairs.push(("warmup", Json::num(batch.cfg.warmup as f64)));
                pairs.push(("fuel", Json::num(batch.cfg.fuel as f64)));
                pairs.push(("tolerance", Json::num(batch.cfg.tolerance)));
                pairs.push(("specs", Json::Arr(batch.specs.iter().map(spec_to_json).collect())));
            }
            Frame::MeasureResult { id, results } => {
                pairs.push(("id", Json::num(*id as f64)));
                pairs.push(("results", Json::Arr(results.iter().map(outcome_to_json).collect())));
            }
            Frame::Heartbeat { seq } => {
                pairs.push(("seq", Json::num(*seq as f64)));
            }
            Frame::Drain | Frame::Bye => {}
        }
        Json::obj(pairs)
    }

    /// Decode from a JSON payload value (inverse of [`Frame::to_json`]).
    pub fn from_json(v: &Json) -> Result<Frame> {
        Ok(match v.get("frame")?.as_str()? {
            "hello" => Frame::Hello {
                protocol: v.get("protocol")?.as_str()?.to_string(),
                caps: Capabilities {
                    gpu: as_bool(v.get("gpu")?)?,
                    fpga: as_bool(v.get("fpga")?)?,
                    device: v.get("device")?.as_str()?.to_string(),
                    max_inflight: v.get("max_inflight")?.as_usize()?,
                },
            },
            "measure-batch" => Frame::MeasureBatch {
                id: v.get("id")?.as_f64()? as u64,
                batch: WireBatch {
                    source: v.get("source")?.as_str()?.to_string(),
                    entry: v.get("entry")?.as_str()?.to_string(),
                    blocks: v
                        .get("blocks")?
                        .as_arr()?
                        .iter()
                        .map(plan_from_json)
                        .collect::<Result<_>>()?,
                    cfg: VerifyConfig {
                        reps: v.get("reps")?.as_usize()?,
                        warmup: v.get("warmup")?.as_usize()?,
                        fuel: v.get("fuel")?.as_f64()? as u64,
                        tolerance: v.get("tolerance")?.as_f64()?,
                    },
                    specs: v
                        .get("specs")?
                        .as_arr()?
                        .iter()
                        .map(spec_from_json)
                        .collect::<Result<_>>()?,
                },
            },
            "measure-result" => Frame::MeasureResult {
                id: v.get("id")?.as_f64()? as u64,
                results: v
                    .get("results")?
                    .as_arr()?
                    .iter()
                    .map(outcome_from_json)
                    .collect::<Result<_>>()?,
            },
            "heartbeat" => Frame::Heartbeat { seq: v.get("seq")?.as_f64()? as u64 },
            "drain" => Frame::Drain,
            "bye" => Frame::Bye,
            other => bail!("unknown fleet frame {other:?}"),
        })
    }
}

fn as_bool(v: &Json) -> Result<bool> {
    match v {
        Json::Bool(b) => Ok(*b),
        other => bail!("expected JSON bool, got {other:?}"),
    }
}

fn spec_to_json(s: &PatternSpec) -> Json {
    Json::obj(vec![
        ("enabled", Json::Arr(s.enabled.iter().map(|&b| Json::Bool(b)).collect())),
        ("label", Json::str(&s.label)),
    ])
}

fn spec_from_json(v: &Json) -> Result<PatternSpec> {
    Ok(PatternSpec {
        enabled: v.get("enabled")?.as_arr()?.iter().map(as_bool).collect::<Result<_>>()?,
        label: v.get("label")?.as_str()?.to_string(),
    })
}

/// Intern a wire type name against the interpreter's known result type
/// names — [`ResultProbe::type_name`] is `&'static str`, so the decode
/// side must map onto the same statics the local executor would produce.
fn intern_type_name(s: &str) -> Result<&'static str> {
    Ok(match s {
        "int" => "int",
        "float" => "float",
        "array" => "array",
        "struct" => "struct",
        "string" => "string",
        "void" => "void",
        other => bail!("unknown result type name {other:?}"),
    })
}

fn measured_to_json(m: &MeasuredPattern) -> Json {
    Json::obj(vec![
        ("time", measurement_to_json(&m.time)),
        ("num", m.probe.num.map(Json::num).unwrap_or(Json::Null)),
        ("type", Json::str(m.probe.type_name)),
        ("output", Json::str(&m.output)),
        ("traffic", traffic_to_json(&m.traffic)),
    ])
}

fn measured_from_json(v: &Json) -> Result<MeasuredPattern> {
    Ok(MeasuredPattern {
        time: measurement_from_json(v.get("time")?)?,
        probe: ResultProbe {
            num: v.opt("num").map(|n| n.as_f64()).transpose()?,
            type_name: intern_type_name(v.get("type")?.as_str()?)?,
        },
        output: v.get("output")?.as_str()?.to_string(),
        traffic: traffic_from_json(v.get("traffic")?)?,
    })
}

fn outcome_to_json(o: &WireOutcome) -> Json {
    match o {
        WireOutcome::Ok(m) => Json::obj(vec![("ok", measured_to_json(m))]),
        WireOutcome::Err { message, detail } => Json::obj(vec![(
            "err",
            Json::obj(vec![("message", Json::str(message)), ("detail", Json::str(detail))]),
        )]),
    }
}

fn outcome_from_json(v: &Json) -> Result<WireOutcome> {
    if let Some(ok) = v.opt("ok") {
        return Ok(WireOutcome::Ok(measured_from_json(ok)?));
    }
    let err = v.get("err")?;
    Ok(WireOutcome::Err {
        message: err.get("message")?.as_str()?.to_string(),
        detail: err.get("detail")?.as_str()?.to_string(),
    })
}

impl WireOutcome {
    /// Digest a local measurement result for the wire.
    pub fn of(result: &Result<MeasuredPattern>) -> WireOutcome {
        match result {
            Ok(m) => WireOutcome::Ok(m.clone()),
            Err(e) => WireOutcome::Err { message: format!("{e}"), detail: format!("{e:#}") },
        }
    }

    /// Reconstruct the local measurement result. The error carries only
    /// the worker's top-level message, so the search resolves a remotely
    /// failed pattern to the exact label a local executor would produce.
    pub fn into_result(self) -> Result<MeasuredPattern> {
        match self {
            WireOutcome::Ok(m) => Ok(m),
            WireOutcome::Err { message, .. } => Err(anyhow!(message)),
        }
    }
}

/// Write one length-prefixed frame and flush the transport.
pub fn write_frame(w: &mut dyn Write, frame: &Frame) -> Result<()> {
    let payload = json::to_string_compact(&frame.to_json());
    w.write_all(format!("{}\n", payload.len()).as_bytes())
        .and_then(|()| w.write_all(payload.as_bytes()))
        .and_then(|()| w.write_all(b"\n"))
        .and_then(|()| w.flush())
        .with_context(|| format!("writing {} frame", frame.name()))?;
    Ok(())
}

/// Read one length-prefixed frame. EOF before the length line, a
/// non-decimal length, an oversized length, a truncated payload, or a
/// payload that is not a valid frame are all errors — the connection is
/// out of sync and must be dropped (never retried on the same stream).
pub fn read_frame(r: &mut dyn BufRead) -> Result<Frame> {
    let mut line = String::new();
    let n = r.read_line(&mut line).context("reading frame length")?;
    if n == 0 {
        bail!("connection closed before a frame length");
    }
    let text = line.trim_end_matches('\n');
    let len: usize = text
        .parse()
        .ok()
        .filter(|_| !text.is_empty() && text.bytes().all(|b| b.is_ascii_digit()))
        .ok_or_else(|| anyhow!("malformed frame length {text:?}"))?;
    if len > MAX_FRAME_BYTES {
        bail!("frame of {len} bytes exceeds the {MAX_FRAME_BYTES}-byte limit");
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).context("reading frame payload")?;
    let mut nl = [0u8; 1];
    r.read_exact(&mut nl).context("reading frame terminator")?;
    if nl[0] != b'\n' {
        bail!("frame payload not terminated by a newline");
    }
    let payload = std::str::from_utf8(&buf).context("frame payload is not UTF-8")?;
    Frame::from_json(&json::parse(payload).context("parsing frame payload")?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;
    use std::time::Duration;

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Hello {
                protocol: PROTOCOL.to_string(),
                caps: Capabilities {
                    gpu: true,
                    fpga: false,
                    device: "pjrt-cpu".to_string(),
                    max_inflight: 2,
                },
            },
            Frame::MeasureBatch {
                id: 1,
                batch: WireBatch {
                    source: "int main() { return 0; }".to_string(),
                    entry: "main".to_string(),
                    blocks: vec![],
                    cfg: VerifyConfig {
                        reps: 1,
                        warmup: 0,
                        fuel: 1_000_000,
                        tolerance: 0.01,
                    },
                    specs: vec![PatternSpec { enabled: vec![], label: "all-CPU".to_string() }],
                },
            },
            Frame::MeasureResult {
                id: 1,
                results: vec![
                    WireOutcome::Ok(MeasuredPattern {
                        time: crate::metrics::Measurement {
                            label: "all-CPU".to_string(),
                            median: Duration::from_nanos(90_000),
                            min: Duration::from_nanos(88_000),
                            max: Duration::from_nanos(91_000),
                            reps: 1,
                        },
                        probe: ResultProbe { num: Some(42.0), type_name: "float" },
                        output: "ok\n".to_string(),
                        traffic: Default::default(),
                    }),
                    WireOutcome::Err {
                        message: "no run completed".to_string(),
                        detail: "no run completed: fuel exhausted".to_string(),
                    },
                ],
            },
            Frame::Heartbeat { seq: 7 },
            Frame::Drain,
            Frame::Bye,
        ]
    }

    #[test]
    fn every_frame_round_trips_byte_identically() {
        for frame in sample_frames() {
            let mut bytes = Vec::new();
            write_frame(&mut bytes, &frame).unwrap();
            let mut reader = BufReader::new(bytes.as_slice());
            let back = read_frame(&mut reader).unwrap();
            assert_eq!(back.name(), frame.name());
            let mut again = Vec::new();
            write_frame(&mut again, &back).unwrap();
            assert_eq!(again, bytes, "codec must be byte-stable for {}", frame.name());
        }
    }

    #[test]
    fn a_stream_of_frames_reads_in_order() {
        let frames = sample_frames();
        let mut bytes = Vec::new();
        for f in &frames {
            write_frame(&mut bytes, f).unwrap();
        }
        let mut reader = BufReader::new(bytes.as_slice());
        for f in &frames {
            assert_eq!(read_frame(&mut reader).unwrap().name(), f.name());
        }
        let err = read_frame(&mut reader).unwrap_err();
        assert!(format!("{err}").contains("closed"), "{err}");
    }

    #[test]
    fn garbage_is_rejected_not_misread() {
        for garbage in [
            "not a length\n",
            "-5\n",
            "18\nshort\n",
            "3\nabc!", // missing terminator
            "2\n{}\n", // valid JSON, not a frame
        ] {
            let mut reader = BufReader::new(garbage.as_bytes());
            assert!(read_frame(&mut reader).is_err(), "garbage accepted: {garbage:?}");
        }
        let huge = format!("{}\n", MAX_FRAME_BYTES + 1);
        let mut reader = BufReader::new(huge.as_bytes());
        let err = read_frame(&mut reader).unwrap_err();
        assert!(format!("{err}").contains("exceeds"), "{err}");
    }

    #[test]
    fn failed_outcomes_keep_the_local_error_text() {
        let err: Result<MeasuredPattern> =
            Err(anyhow!("inner cause").context("measuring only:call:fft2d"));
        let wire = WireOutcome::of(&err);
        let back = wire.into_result().unwrap_err();
        // Labels resolved from this error must match the local executor's,
        // which formats with `{e}` (top-level message only).
        assert_eq!(format!("{back}"), "measuring only:call:fft2d");
    }

    #[test]
    fn unknown_result_type_names_are_rejected() {
        assert!(intern_type_name("float").is_ok());
        assert!(intern_type_name("quaternion").is_err());
    }
}
