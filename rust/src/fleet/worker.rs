//! The fleet worker: a measurement box behind the wire protocol.
//!
//! A worker hosts one PJRT engine (plus `max_inflight - 1` measure-only
//! sibling engines via [`MeasurePool`] when capabilities allow more than
//! one in-flight pattern) and speaks `fbo-fleet-v1` over whatever
//! transport the CLI selected: a TCP listener (`fbo worker --listen
//! ADDR`) or its own stdio pipe (`fbo worker --stdio`, for
//! spawned-child fleets). The protocol logic is transport-agnostic —
//! [`WorkerHost::serve_connection`] takes any `BufRead`/`Write` pair, so
//! tests drive it over in-process sockets.
//!
//! A batch is executed with the same machinery a local verify run uses:
//! the shipped source is re-parsed, a [`VerifyContext`] is rebuilt, and
//! every spec runs through the exact `measure_spec` path a
//! [`crate::coordinator::SerialExecutor`] would take — which is what
//! keeps fleet decisions byte-identical to local ones.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::verify::VerifyContext;
use crate::coordinator::{PatternExecutor, SerialExecutor};
use crate::parser;
use crate::runtime::Engine;
use crate::service::MeasurePool;

use super::wire::{read_frame, write_frame, Capabilities, Frame, WireBatch, WireOutcome, PROTOCOL};

/// One worker process: an engine (plus optional measure-only siblings)
/// and the capabilities it announces. Reusable across connections — the
/// engine and its artifact compile cache persist between schedulers.
pub struct WorkerHost {
    caps: Capabilities,
    executor: Box<dyn PatternExecutor>,
    /// Keeps the sibling engines alive; the executor only holds senders.
    _pool: Option<MeasurePool>,
}

impl WorkerHost {
    /// Open the engine(s) over an artifact directory. With
    /// `caps.max_inflight > 1` a [`MeasurePool`] of sibling engines is
    /// started so one batch's patterns measure concurrently.
    pub fn open(artifacts: &Path, caps: Capabilities) -> Result<WorkerHost> {
        let engine = Engine::open(artifacts)?;
        let (executor, pool): (Box<dyn PatternExecutor>, Option<MeasurePool>) =
            if caps.max_inflight > 1 {
                let pool = MeasurePool::start(artifacts, caps.max_inflight - 1)?;
                (Box::new(pool.executor(engine, caps.max_inflight)), Some(pool))
            } else {
                (Box::new(SerialExecutor::new(engine)), None)
            };
        Ok(WorkerHost { caps, executor, _pool: pool })
    }

    /// The capabilities this worker announces in its hello frame.
    pub fn caps(&self) -> &Capabilities {
        &self.caps
    }

    /// Measure one wire batch, producing index-aligned outcomes. A batch
    /// whose source does not parse fails every spec with that error —
    /// alignment with the scheduler's plan is preserved no matter what.
    pub fn measure_batch(&self, batch: &WireBatch) -> Vec<WireOutcome> {
        let prog = match parser::parse(&batch.source) {
            Ok(p) => p,
            Err(e) => {
                let err = e.context("parsing the shipped program source");
                let outcome = WireOutcome::Err {
                    message: format!("{err}"),
                    detail: format!("{err:#}"),
                };
                return batch.specs.iter().map(|_| outcome.clone()).collect();
            }
        };
        let ctx = VerifyContext {
            prog: &prog,
            entry: &batch.entry,
            blocks: &batch.blocks,
            cfg: &batch.cfg,
            // Cost hints order dispatch on the scheduler side and are not
            // part of the frozen fbo-fleet-v1 wire batch.
            cost_hints: &[],
        };
        self.executor.measure(&ctx, &batch.specs).iter().map(WireOutcome::of).collect()
    }

    /// Serve one scheduler connection: send the hello frame, then answer
    /// measure batches and heartbeats until the scheduler drains or says
    /// bye. Returns `Ok` on a clean close, `Err` when the connection
    /// broke or desynchronized (a garbage frame); either way the host
    /// stays usable for the next connection.
    pub fn serve_connection(&self, r: &mut dyn BufRead, w: &mut dyn Write) -> Result<()> {
        write_frame(w, &Frame::Hello { protocol: PROTOCOL.to_string(), caps: self.caps.clone() })?;
        loop {
            match read_frame(r)? {
                Frame::MeasureBatch { id, batch } => {
                    let results = self.measure_batch(&batch);
                    write_frame(w, &Frame::MeasureResult { id, results })?;
                }
                Frame::Heartbeat { seq } => write_frame(w, &Frame::Heartbeat { seq })?,
                Frame::Drain => {
                    write_frame(w, &Frame::Bye)?;
                    return Ok(());
                }
                Frame::Bye => return Ok(()),
                other => bail!("unexpected {} frame from the scheduler", other.name()),
            }
        }
    }

    /// Serve the worker's own stdio pipe (the `fbo worker --stdio`
    /// transport): frames on stdin/stdout, logs on stderr. Returns when
    /// the scheduler drains, says bye, or closes the pipe.
    pub fn serve_stdio(&self) -> Result<()> {
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        let mut reader = BufReader::new(stdin.lock());
        let mut writer = stdout.lock();
        self.serve_connection(&mut reader, &mut writer)
    }

    /// Serve a TCP listener (`fbo worker --listen ADDR`): schedulers are
    /// served one connection at a time — the engine is deliberately
    /// single-threaded state, and the fleet model is one front-end
    /// driving many workers, not many front-ends sharing one worker. A
    /// connection that errors is logged to stderr and the loop accepts
    /// the next one.
    pub fn serve_listener(&self, listener: &TcpListener) -> Result<()> {
        loop {
            let (stream, peer) = listener.accept().context("accepting a fleet connection")?;
            stream.set_nodelay(true).ok();
            let mut reader =
                BufReader::new(stream.try_clone().context("cloning the connection stream")?);
            let mut writer = stream;
            if let Err(e) = self.serve_connection(&mut reader, &mut writer) {
                eprintln!("fleet worker: connection from {peer} ended: {e:#}");
            }
        }
    }
}
