//! The worker registry: live fleet membership and per-worker transport.
//!
//! The registry owns one **connection thread** per worker. The scheduler
//! never touches a socket: it hands a batch to a worker's thread over a
//! channel and waits (with a deadline) on a per-batch reply channel, so
//! worker death and slowness surface as channel events the scheduler can
//! act on — re-deal, retry, or fall back — without any transport
//! knowledge. A worker that breaks its connection (EOF, garbage frame,
//! short result) is marked dead and never dealt to again — though a dead
//! **TCP** endpoint gets a bounded number of backoff-gated re-dials on
//! later batch deals ([`FleetRegistry::reconnect_dead`]); the rest of
//! the registry is unaffected either way.
//!
//! Endpoints come in two transports sharing one codec:
//!
//! * `host:port` — JSON-over-TCP to a running `fbo worker --listen`;
//! * `stdio:<command ...>` — spawn the command (typically `fbo worker
//!   --stdio`) as a child and speak frames over its stdio pipe.
//!
//! Shutdown mirrors the service pool's drain-then-stop: the registry
//! sends `drain`, the worker finishes in-flight work and replies `bye`,
//! and only then does the connection thread exit (and a spawned child
//! get reaped).

use std::cell::{Cell, RefCell};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::patterndb::json::fnv1a64;

use super::wire::{read_frame, write_frame, Capabilities, Frame, WireBatch, WireOutcome, PROTOCOL};
use super::Backoff;

/// How long a TCP connect / hello handshake may take before the endpoint
/// is rejected at registry construction.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// Re-dials a dead TCP endpoint gets (per death episode) before the
/// registry gives up on the slot for good. A successful reconnection
/// resets the budget.
const MAX_RECONNECT_ATTEMPTS: u32 = 3;

/// Backoff envelope between reconnection attempts to one endpoint.
const RECONNECT_BACKOFF_BASE: Duration = Duration::from_millis(100);
const RECONNECT_BACKOFF_CAP: Duration = Duration::from_secs(2);

/// One parsed `--fleet` endpoint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FleetEndpoint {
    /// JSON-over-TCP to `host:port`.
    Tcp(String),
    /// Spawn `command` and speak frames over its stdio pipe.
    Stdio(Vec<String>),
}

impl FleetEndpoint {
    /// Parse one endpoint string: `host:port`, or `stdio:<command ...>`
    /// (whitespace-separated argv).
    pub fn parse(s: &str) -> Result<FleetEndpoint> {
        if let Some(cmd) = s.strip_prefix("stdio:") {
            let argv: Vec<String> = cmd.split_whitespace().map(str::to_string).collect();
            if argv.is_empty() {
                bail!("empty stdio fleet endpoint");
            }
            return Ok(FleetEndpoint::Stdio(argv));
        }
        if s.contains(':') {
            return Ok(FleetEndpoint::Tcp(s.to_string()));
        }
        bail!("fleet endpoint {s:?} is neither host:port nor stdio:<command>")
    }

    /// Parse a comma-separated `--fleet` list.
    pub fn parse_list(s: &str) -> Result<Vec<FleetEndpoint>> {
        s.split(',')
            .map(str::trim)
            .filter(|e| !e.is_empty())
            .map(FleetEndpoint::parse)
            .collect()
    }

    /// Stable display label (worker name in stats, metrics, and traces).
    pub fn label(&self) -> String {
        match self {
            FleetEndpoint::Tcp(addr) => format!("tcp:{addr}"),
            FleetEndpoint::Stdio(argv) => format!("stdio:{}", argv[0]),
        }
    }

    /// Render back to the `--fleet` argument form that parses to this
    /// endpoint (the service config carries endpoints as these strings).
    pub fn as_arg(&self) -> String {
        match self {
            FleetEndpoint::Tcp(addr) => addr.clone(),
            FleetEndpoint::Stdio(argv) => format!("stdio:{}", argv.join(" ")),
        }
    }
}

/// A command to a worker's connection thread.
pub(crate) enum WorkerCmd {
    /// Exchange one measure batch; the reply goes to `reply`.
    Batch {
        /// Correlation id (unique per registry).
        id: u64,
        /// The batch to ship.
        batch: WireBatch,
        /// Where the outcome lands. A dropped receiver (scheduler timed
        /// out and moved on) is fine — the send is best-effort.
        reply: mpsc::Sender<Result<Vec<WireOutcome>>>,
    },
    /// Drain and close the connection.
    Drain,
}

/// One live (or dead) fleet worker as the scheduler sees it. The
/// liveness and busy flags are shared with the connection thread; the
/// scheduler itself is single-threaded per search.
pub struct FleetWorker {
    name: String,
    caps: Capabilities,
    endpoint: FleetEndpoint,
    alive: Arc<AtomicBool>,
    busy: Arc<AtomicBool>,
    /// Swapped for a fresh channel when a dead endpoint reconnects.
    tx: RefCell<mpsc::Sender<WorkerCmd>>,
    /// Re-dials spent on the current death episode.
    reconnects: Cell<u32>,
    /// Delay generator between re-dials, seeded per worker name so a
    /// fleet of schedulers does not re-dial a shared box in lockstep.
    backoff: RefCell<Backoff>,
}

impl FleetWorker {
    /// Display name (`tcp:host:port` / `stdio:command`, suffixed with an
    /// index when the same endpoint appears twice).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Capabilities the worker announced in its hello frame.
    pub fn caps(&self) -> &Capabilities {
        &self.caps
    }

    /// False once the worker's connection broke; a dead worker is never
    /// dealt to again.
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }

    /// True while a batch is in flight on this worker's connection —
    /// including a batch the scheduler already timed out on (the
    /// connection thread stays busy until the worker replies or dies).
    pub fn is_busy(&self) -> bool {
        self.busy.load(Ordering::Relaxed)
    }

    /// Ship a batch to the connection thread; the returned receiver
    /// yields the outcome (or disconnects if the worker is gone).
    pub(crate) fn dispatch(
        &self,
        id: u64,
        batch: WireBatch,
    ) -> mpsc::Receiver<Result<Vec<WireOutcome>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.busy.store(true, Ordering::Relaxed);
        if self.tx.borrow().send(WorkerCmd::Batch { id, batch, reply: reply_tx }).is_err() {
            // The connection thread is gone; the dropped sender makes the
            // receiver report Disconnected, which the scheduler treats as
            // worker death.
            self.alive.store(false, Ordering::Relaxed);
        }
        reply_rx
    }

    /// A disconnected stand-in for scheduler unit tests: carries a name
    /// and capabilities but no live transport (dispatching would surface
    /// as worker death, exactly like a real dead worker).
    #[cfg(test)]
    pub(crate) fn stub(name: &str, caps: Capabilities) -> FleetWorker {
        let (tx, _rx) = mpsc::channel();
        FleetWorker {
            name: name.to_string(),
            caps,
            endpoint: FleetEndpoint::Tcp(format!("{name}:0")),
            alive: Arc::new(AtomicBool::new(true)),
            busy: Arc::new(AtomicBool::new(false)),
            tx: RefCell::new(tx),
            reconnects: Cell::new(0),
            backoff: RefCell::new(reconnect_backoff(name)),
        }
    }
}

/// The per-worker reconnection backoff, seeded on the worker name.
fn reconnect_backoff(name: &str) -> Backoff {
    Backoff::new(RECONNECT_BACKOFF_BASE, RECONNECT_BACKOFF_CAP, fnv1a64(name.as_bytes()))
}

/// The connection thread's end of one worker link.
struct Link {
    reader: Box<dyn BufRead + Send>,
    writer: Box<dyn Write + Send>,
    /// A handle to the TCP stream (to clear the handshake read timeout);
    /// stdio links have none.
    stream: Option<TcpStream>,
    /// The spawned child for stdio endpoints, reaped at drain.
    child: Option<Child>,
}

/// The live fleet: one [`FleetWorker`] per successfully-handshaken
/// endpoint, plus the reasons any endpoint was rejected. Dropping the
/// registry drains every worker (drain-then-stop) and joins the
/// connection threads.
pub struct FleetRegistry {
    workers: Vec<FleetWorker>,
    rejected: Vec<String>,
    /// Connection threads, including exited ones for dead workers; a
    /// reconnection pushes a fresh thread (hence the `RefCell` — revival
    /// happens through the scheduler's shared reference).
    threads: RefCell<Vec<JoinHandle<()>>>,
    next_batch: Cell<u64>,
}

impl FleetRegistry {
    /// Connect to every endpoint and validate each hello frame. An
    /// endpoint that cannot connect, speaks the wrong protocol version,
    /// or opens with anything but a hello is **rejected** (recorded in
    /// [`FleetRegistry::rejected`]) without failing the others — an
    /// empty registry simply means every measurement falls back to the
    /// local executor.
    pub fn connect(endpoints: &[FleetEndpoint]) -> FleetRegistry {
        let mut reg = FleetRegistry {
            workers: Vec::new(),
            rejected: Vec::new(),
            threads: RefCell::new(Vec::new()),
            next_batch: Cell::new(0),
        };
        for (i, ep) in endpoints.iter().enumerate() {
            let name = format!("{}#{i}", ep.label());
            match handshake(ep) {
                Ok((link, caps)) => {
                    let alive = Arc::new(AtomicBool::new(true));
                    let busy = Arc::new(AtomicBool::new(false));
                    let (tx, rx) = mpsc::channel();
                    let thread_alive = alive.clone();
                    let thread_busy = busy.clone();
                    match std::thread::Builder::new()
                        .name(format!("fbo-fleet-{i}"))
                        .spawn(move || link_main(link, rx, thread_alive, thread_busy))
                    {
                        Ok(handle) => {
                            reg.threads.borrow_mut().push(handle);
                            let backoff = RefCell::new(reconnect_backoff(&name));
                            reg.workers.push(FleetWorker {
                                name,
                                caps,
                                endpoint: ep.clone(),
                                alive,
                                busy,
                                tx: RefCell::new(tx),
                                reconnects: Cell::new(0),
                                backoff,
                            });
                        }
                        Err(e) => reg.rejected.push(format!("{name}: spawning link thread: {e}")),
                    }
                }
                Err(e) => reg.rejected.push(format!("{name}: {e:#}")),
            }
        }
        reg
    }

    /// Re-dial every dead TCP worker whose reconnection budget is not
    /// exhausted, sleeping the worker's jittered exponential backoff
    /// before each dial. A revived worker keeps its slot (same name, same
    /// announced capabilities — a box that comes back with *different*
    /// capabilities is a different worker and is turned away); success
    /// resets its budget and backoff for the next death episode. Stdio
    /// workers are never revived — their child exited, and respawning is
    /// the operator's call. `observe` sees every attempt as
    /// `(worker, attempt, delay_ms, ok)`. Returns how many came back.
    pub fn reconnect_dead(&self, mut observe: impl FnMut(&str, u64, u64, bool)) -> usize {
        let mut revived = 0;
        for w in &self.workers {
            if w.is_alive()
                || !matches!(w.endpoint, FleetEndpoint::Tcp(_))
                || w.reconnects.get() >= MAX_RECONNECT_ATTEMPTS
            {
                continue;
            }
            let delay = w.backoff.borrow_mut().next_delay();
            std::thread::sleep(delay);
            let attempt = u64::from(w.reconnects.get()) + 1;
            w.reconnects.set(w.reconnects.get() + 1);
            let ok = match handshake(&w.endpoint) {
                Ok((link, caps)) if caps == w.caps => {
                    let (tx, rx) = mpsc::channel();
                    let thread_alive = w.alive.clone();
                    let thread_busy = w.busy.clone();
                    w.busy.store(false, Ordering::Relaxed);
                    match std::thread::Builder::new()
                        .name(format!("{}-r{attempt}", w.name))
                        .spawn(move || link_main(link, rx, thread_alive, thread_busy))
                    {
                        Ok(handle) => {
                            self.threads.borrow_mut().push(handle);
                            *w.tx.borrow_mut() = tx;
                            w.alive.store(true, Ordering::Relaxed);
                            true
                        }
                        Err(_) => false,
                    }
                }
                Ok((mut link, _)) => {
                    // The endpoint answers but announces different
                    // capabilities: batches scheduled against the old
                    // profile would mis-deal, so leave the slot dead.
                    let _ = write_frame(&mut link.writer, &Frame::Bye);
                    false
                }
                Err(_) => false,
            };
            observe(&w.name, attempt, delay.as_millis() as u64, ok);
            if ok {
                w.reconnects.set(0);
                w.backoff.borrow_mut().reset();
                revived += 1;
            }
        }
        revived
    }

    /// Every registered worker, dead ones included (stable order).
    pub fn workers(&self) -> &[FleetWorker] {
        &self.workers
    }

    /// Workers still alive.
    pub fn live(&self) -> Vec<&FleetWorker> {
        self.workers.iter().filter(|w| w.is_alive()).collect()
    }

    /// Number of workers still alive.
    pub fn live_count(&self) -> usize {
        self.workers.iter().filter(|w| w.is_alive()).count()
    }

    /// Why endpoints were rejected at connect time (version mismatches,
    /// connect failures), in endpoint order.
    pub fn rejected(&self) -> &[String] {
        &self.rejected
    }

    /// Allocate the next batch correlation id.
    pub(crate) fn next_batch_id(&self) -> u64 {
        let id = self.next_batch.get() + 1;
        self.next_batch.set(id);
        id
    }

    /// Drain-then-stop: tell every connection thread to finish its
    /// in-flight batch, send `drain`, await `bye`, and exit. Joins the
    /// threads (and reaps spawned children). Idempotent.
    pub fn drain(&mut self) {
        for w in &self.workers {
            let _ = w.tx.borrow().send(WorkerCmd::Drain);
        }
        for t in self.threads.borrow_mut().drain(..) {
            let _ = t.join();
        }
        for w in &self.workers {
            w.alive.store(false, Ordering::Relaxed);
        }
    }
}

impl Drop for FleetRegistry {
    fn drop(&mut self) {
        self.drain();
    }
}

/// Open the transport and validate the worker's hello frame.
fn handshake(ep: &FleetEndpoint) -> Result<(Link, Capabilities)> {
    let mut link = open_link(ep)?;
    let hello = read_frame(&mut link.reader).context("reading the hello frame")?;
    match hello {
        Frame::Hello { protocol, caps } if protocol == PROTOCOL => {
            // The handshake is bounded; steady-state reads block until
            // the scheduler-side batch deadline decides otherwise.
            if let Some(stream) = &link.stream {
                stream.set_read_timeout(None).ok();
            }
            Ok((link, caps))
        }
        Frame::Hello { protocol, .. } => {
            let _ = write_frame(&mut link.writer, &Frame::Bye);
            bail!("worker speaks protocol {protocol:?}, this scheduler wants {PROTOCOL:?}")
        }
        other => bail!("worker opened with a {} frame instead of hello", other.name()),
    }
}

fn open_link(ep: &FleetEndpoint) -> Result<Link> {
    match ep {
        FleetEndpoint::Tcp(addr) => {
            let sock = addr
                .to_socket_addrs()
                .with_context(|| format!("resolving fleet endpoint {addr:?}"))?
                .next()
                .ok_or_else(|| anyhow!("fleet endpoint {addr:?} resolved to no address"))?;
            let stream = TcpStream::connect_timeout(&sock, CONNECT_TIMEOUT)
                .with_context(|| format!("connecting to fleet worker {addr}"))?;
            stream.set_nodelay(true).ok();
            // Bound the handshake; cleared after the hello frame lands.
            stream.set_read_timeout(Some(CONNECT_TIMEOUT)).ok();
            let reader = BufReader::new(stream.try_clone().context("cloning the stream")?);
            let handle = stream.try_clone().context("cloning the stream")?;
            Ok(Link {
                reader: Box::new(reader),
                writer: Box::new(stream),
                stream: Some(handle),
                child: None,
            })
        }
        FleetEndpoint::Stdio(argv) => {
            let mut child = Command::new(&argv[0])
                .args(&argv[1..])
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .spawn()
                .with_context(|| format!("spawning fleet worker {:?}", argv[0]))?;
            let stdin = child.stdin.take().expect("piped stdin");
            let stdout = child.stdout.take().expect("piped stdout");
            Ok(Link {
                reader: Box::new(BufReader::new(stdout)),
                writer: Box::new(stdin),
                stream: None,
                child: Some(child),
            })
        }
    }
}

/// One worker's connection thread: exchange batches serially, mark the
/// worker dead on any wire error, drain on command.
fn link_main(
    mut link: Link,
    rx: mpsc::Receiver<WorkerCmd>,
    alive: Arc<AtomicBool>,
    busy: Arc<AtomicBool>,
) {
    let mut clean = true;
    while let Ok(cmd) = rx.recv() {
        match cmd {
            WorkerCmd::Batch { id, batch, reply } => {
                let outcome = exchange(&mut link, id, &batch);
                let broke = outcome.is_err();
                busy.store(false, Ordering::Relaxed);
                let _ = reply.send(outcome);
                if broke {
                    alive.store(false, Ordering::Relaxed);
                    clean = false;
                    break;
                }
            }
            WorkerCmd::Drain => break,
        }
    }
    if clean {
        // Drain-then-stop: mirror the pool's shutdown so the worker can
        // exit (or serve its next scheduler) cleanly.
        let _ = write_frame(&mut link.writer, &Frame::Drain);
        loop {
            match read_frame(&mut link.reader) {
                Ok(Frame::Bye) | Err(_) => break,
                Ok(_) => continue,
            }
        }
    }
    alive.store(false, Ordering::Relaxed);
    if let Some(mut child) = link.child {
        let _ = child.wait();
    }
}

/// Ship one batch and read frames until its result arrives. Stale
/// results (from a batch the scheduler abandoned) and heartbeats are
/// skipped; anything else desynchronizes the connection.
fn exchange(link: &mut Link, id: u64, batch: &WireBatch) -> Result<Vec<WireOutcome>> {
    write_frame(&mut link.writer, &Frame::MeasureBatch { id, batch: batch.clone() })?;
    loop {
        match read_frame(&mut link.reader)? {
            Frame::MeasureResult { id: got, results } if got == id => {
                if results.len() != batch.specs.len() {
                    bail!(
                        "worker returned {} results for {} planned patterns",
                        results.len(),
                        batch.specs.len()
                    );
                }
                return Ok(results);
            }
            Frame::MeasureResult { .. } | Frame::Heartbeat { .. } => continue,
            other => bail!("unexpected {} frame while awaiting batch {id}", other.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_parsing_covers_both_transports() {
        assert_eq!(
            FleetEndpoint::parse("worker1:7070").unwrap(),
            FleetEndpoint::Tcp("worker1:7070".to_string())
        );
        let stdio = FleetEndpoint::parse("stdio:fbo worker --stdio").unwrap();
        assert_eq!(
            stdio,
            FleetEndpoint::Stdio(vec![
                "fbo".to_string(),
                "worker".to_string(),
                "--stdio".to_string()
            ])
        );
        assert_eq!(stdio.label(), "stdio:fbo");
        assert_eq!(FleetEndpoint::parse(&stdio.as_arg()).unwrap(), stdio, "as_arg round-trips");
        assert!(FleetEndpoint::parse("no-port").is_err());
        assert!(FleetEndpoint::parse("stdio:").is_err());
        let list = FleetEndpoint::parse_list("a:1, b:2 ,").unwrap();
        assert_eq!(list.len(), 2);
        assert_eq!(list[1], FleetEndpoint::Tcp("b:2".to_string()));
    }

    #[test]
    fn unreachable_endpoints_are_rejected_not_fatal() {
        // Port 1 on localhost is essentially never listening; the
        // registry must record the rejection and stay usable.
        let reg = FleetRegistry::connect(&[FleetEndpoint::Tcp("127.0.0.1:1".to_string())]);
        assert_eq!(reg.live_count(), 0);
        assert_eq!(reg.rejected().len(), 1);
        assert!(reg.rejected()[0].starts_with("tcp:127.0.0.1:1#0"), "{:?}", reg.rejected());
    }
}
