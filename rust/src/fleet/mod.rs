//! Distributed **measurement fleet**: remote verify workers behind a
//! capability-aware scheduler.
//!
//! The paper's dominant cost is Step-3 verification — compiling and
//! measuring candidate patterns on real GPU/FPGA hardware — and the
//! companion proposal (arXiv:2004.09883) assumes a *verification
//! environment* of many heterogeneous boxes, not one machine. PR 4's
//! plan/measure/reduce split already made every pattern measurement a
//! self-contained, serializable job; this module adds the missing
//! subsystem around it:
//!
//! * [`wire`] — the `fbo-fleet-v1` frame protocol: versioned,
//!   length-prefixed canonical-JSON frames (`hello`, `measure-batch`,
//!   `measure-result`, `heartbeat`, `drain`, `bye`) running unchanged
//!   over JSON-over-TCP and over a spawned child's stdio pipe.
//! * [`worker`] — the remote end (`fbo worker --listen ADDR | --stdio`):
//!   hosts a PJRT engine (plus optional measure-only siblings via
//!   [`crate::service::MeasurePool`]) and announces capability tags
//!   (gpu/fpga, device model, max in-flight) in its hello frame.
//! * [`registry`] — live worker bookkeeping: one connection thread per
//!   worker, hello/version validation, liveness flags, and the
//!   drain-then-stop shutdown that mirrors the service pool's.
//! * [`scheduler`] — [`scheduler::FleetExecutor`], a
//!   [`crate::coordinator::PatternExecutor`] that partitions a verify
//!   plan's independent measurements across live workers by capability
//!   and estimated cost, reduces index-aligned, and handles the failure
//!   matrix: worker death mid-batch re-deals to survivors, a timeout
//!   retries with jittered backoff, and a pattern no worker can measure
//!   falls back to the local executor. Decisions stay byte-identical to
//!   [`crate::coordinator::SerialExecutor`] — the fleet buys wall-clock,
//!   never a different answer.
//!
//! The fleet is **fingerprint-passive**: like `verify_parallel`, the
//! `--fleet` endpoint list is excluded from every cache fingerprint, so
//! fleet-verified and locally-verified decisions replay each other's
//! cache entries byte-identically.

use std::time::Duration;

pub mod registry;
pub mod scheduler;
pub mod wire;
pub mod worker;

pub use registry::{FleetEndpoint, FleetRegistry, FleetWorker};
pub use scheduler::{FleetExecutor, FleetStats, FleetTelemetry};
pub use wire::{Capabilities, Frame, WireBatch, WireOutcome, PROTOCOL};
pub use worker::WorkerHost;

/// Jittered exponential backoff, shared by the fleet scheduler's re-deal
/// retries and the `fbo batch` client's retry-after handling.
///
/// The delay for attempt *k* is `min(cap, base * 2^k)` scaled by a
/// deterministic jitter in `[0.5, 1.0)` derived from the seed — callers
/// pass a per-job seed so concurrent clients spread out instead of
/// retrying in lockstep, while tests stay reproducible.
#[derive(Debug, Clone)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    seed: u64,
    attempt: u32,
}

impl Backoff {
    /// Backoff starting at `base`, doubling per attempt, capped at `cap`.
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Backoff {
        Backoff { base, cap, seed, attempt: 0 }
    }

    /// Attempts taken so far (i.e. how many delays were handed out).
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// The next delay: exponential, capped, jittered. Advances the
    /// attempt counter.
    pub fn next_delay(&mut self) -> Duration {
        let exp = self
            .base
            .checked_mul(1u32.checked_shl(self.attempt).unwrap_or(u32::MAX))
            .map_or(self.cap, |d| d.min(self.cap));
        self.attempt = self.attempt.saturating_add(1);
        jitter(exp, self.seed, self.attempt)
    }

    /// The next delay, floored at a server-provided `retry_after` hint —
    /// the `fbo batch` client honors [`crate::service::JobRejected`]'s
    /// hint while still spreading concurrent retries with jitter.
    pub fn next_delay_after(&mut self, retry_after: Duration) -> Duration {
        self.next_delay().max(retry_after)
    }

    /// Reset the attempt counter (after a successful call).
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

/// Scale `d` by a deterministic factor in `[0.5, 1.0)` keyed on
/// `(seed, attempt)`.
fn jitter(d: Duration, seed: u64, attempt: u32) -> Duration {
    let mut key = [0u8; 12];
    key[..8].copy_from_slice(&seed.to_le_bytes());
    key[8..].copy_from_slice(&attempt.to_le_bytes());
    let h = crate::patterndb::json::fnv1a64(&key);
    let frac = 0.5 + (h % 1_000_000) as f64 / 2_000_000.0;
    d.mul_f64(frac)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_exponentially_and_caps() {
        let mut b = Backoff::new(Duration::from_millis(100), Duration::from_secs(2), 1);
        let delays: Vec<Duration> = (0..8).map(|_| b.next_delay()).collect();
        // Jitter scales into [0.5, 1.0), so each delay is at least half
        // its un-jittered envelope and below the envelope itself.
        for (i, d) in delays.iter().enumerate() {
            let envelope =
                Duration::from_millis(100 * (1u64 << i.min(5))).min(Duration::from_secs(2));
            assert!(*d >= envelope / 2, "attempt {i}: {d:?} under half of {envelope:?}");
            assert!(*d <= envelope, "attempt {i}: {d:?} over {envelope:?}");
        }
        assert!(delays[7] <= Duration::from_secs(2));
        assert_eq!(b.attempts(), 8);
        b.reset();
        assert_eq!(b.attempts(), 0);
    }

    #[test]
    fn backoff_is_deterministic_per_seed_and_jittered_across_seeds() {
        let delays = |seed: u64| -> Vec<Duration> {
            let mut b = Backoff::new(Duration::from_millis(50), Duration::from_secs(1), seed);
            (0..4).map(|_| b.next_delay()).collect()
        };
        assert_eq!(delays(7), delays(7), "same seed must reproduce");
        assert_ne!(delays(7), delays(8), "different seeds must spread out");
    }

    #[test]
    fn retry_after_hint_is_a_floor() {
        let mut b = Backoff::new(Duration::from_millis(1), Duration::from_millis(8), 3);
        let hint = Duration::from_millis(250);
        assert!(b.next_delay_after(hint) >= hint);
    }
}
