//! The fleet scheduler: a [`PatternExecutor`] that deals a verify plan's
//! independent measurements across live workers.
//!
//! Scheduling is deterministic and capability-aware. Each pattern's
//! *need* is the union of its enabled blocks' target kinds (a GPU-library
//! block needs `gpu`, an FPGA IP-core block needs `fpga`; the all-CPU
//! baseline needs nothing), and a pattern is only dealt to a worker whose
//! announced capabilities cover that need. Within the capable set the
//! deal is greedy longest-processing-time: patterns sorted by estimated
//! cost (fewer offloaded blocks run longer on the interpreter) land on
//! the worker with the least accumulated cost, so a 2-worker fleet splits
//! a phase-1 sweep roughly evenly instead of round-robining the slow
//! all-CPU-ish patterns onto one box. When the estimate stage supplied
//! per-block cost hints ([`VerifyContext::cost_hints`]), the predicted
//! device seconds refine that ordering among patterns with the same
//! interpreter burden; without hints the deal reduces to exactly the
//! block-count heuristic.
//!
//! The failure matrix, in order of detection:
//!
//! * **no live workers** — every pattern measures on the local fallback
//!   executor (the fleet degrades to exactly the non-fleet behavior);
//! * **no capable worker for a pattern** — that pattern measures locally
//!   in the same round, concurrently with the remote batches;
//! * **worker death mid-batch** — its patterns re-deal to the survivors
//!   after a jittered backoff, and the dead TCP endpoint is re-dialed on
//!   the next batch deal (bounded attempts, jittered exponential delay,
//!   one `fleet-reconnect` trace event per attempt);
//! * **batch timeout** — the worker is left marked busy (its connection
//!   thread keeps waiting; a late reply just clears the flag) and the
//!   batch re-deals elsewhere;
//! * **retries exhausted** — whatever is still unmeasured falls back to
//!   the local executor.
//!
//! Whatever the path, the outcome vector stays index-aligned with the
//! specs and each outcome is byte-identical to what
//! [`crate::coordinator::SerialExecutor`] would produce — including
//! failed measurements, whose wire error text reconstructs the same
//! resolved label.

use std::cell::Cell;
use std::rc::Rc;
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::verify::{MeasuredPattern, PatternSpec, VerifyContext};
use crate::coordinator::PatternExecutor;
use crate::parser;
use crate::patterndb::json::fnv1a64;
use crate::patterndb::TargetKind;
use crate::telemetry::{Registry, TraceEvent, TraceRecorder};
use crate::transform::PlannedReplacement;

use super::registry::{FleetRegistry, FleetWorker};
use super::wire::{Capabilities, WireBatch, WireOutcome};
use super::Backoff;

/// Default per-round deadline for a remote batch. Measurement batches
/// run whole programs repeatedly, so the default is generous; tighten it
/// with [`FleetExecutor::with_timeout`] (tests use tens of milliseconds).
const DEFAULT_BATCH_TIMEOUT: Duration = Duration::from_secs(600);

/// Re-deal rounds after the first before the remainder falls back to the
/// local executor.
const DEFAULT_MAX_RETRIES: u32 = 2;

/// Backoff envelope between re-deal rounds.
const REDEAL_BACKOFF_BASE: Duration = Duration::from_millis(50);
const REDEAL_BACKOFF_CAP: Duration = Duration::from_secs(2);

/// Counters the fleet executor keeps about its own scheduling (distinct
/// from wire-level telemetry): where patterns were measured and how often
/// a round had to be re-dealt.
#[derive(Debug, Default)]
pub struct FleetStats {
    remote: Cell<u64>,
    local: Cell<u64>,
    redeals: Cell<u64>,
}

impl FleetStats {
    /// Patterns whose measurement came back from a fleet worker.
    pub fn remote(&self) -> u64 {
        self.remote.get()
    }

    /// Patterns measured by the local fallback executor (no capable or
    /// live worker, or retries exhausted).
    pub fn local(&self) -> u64 {
        self.local.get()
    }

    /// Rounds that re-dealt patterns after a worker death or timeout.
    pub fn redeals(&self) -> u64 {
        self.redeals.get()
    }

    fn bump(cell: &Cell<u64>, n: u64) {
        cell.set(cell.get() + n);
    }
}

/// Fleet observability hooks: per-worker batch counters and dispatch
/// spans. Wired by the service (`--serve`) and the CLI when telemetry is
/// on; the executor works fine without it.
pub struct FleetTelemetry {
    metrics: Arc<Registry>,
    recorder: Arc<TraceRecorder>,
    /// Trace id of the request currently verifying (0 = none) — the same
    /// cell the pool's dispatch sink reads, so fleet spans land on the
    /// right request trace.
    trace: Rc<Cell<u64>>,
}

impl FleetTelemetry {
    /// Hooks writing into `metrics` and recording spans on `recorder`
    /// under whatever trace id `trace` holds at dispatch time.
    pub fn new(
        metrics: Arc<Registry>,
        recorder: Arc<TraceRecorder>,
        trace: Rc<Cell<u64>>,
    ) -> FleetTelemetry {
        FleetTelemetry { metrics, recorder, trace }
    }

    fn workers(&self, live: usize) {
        self.metrics.gauge("fbo_fleet_workers", "Live fleet workers.", &[]).set(live as f64);
    }

    fn batch(&self, worker: &str, patterns: usize, wall: Duration, outcome: &str) {
        self.metrics
            .counter(
                "fbo_fleet_batches_total",
                "Fleet measure batches by worker and outcome.",
                &[("worker", worker), ("outcome", outcome)],
            )
            .inc();
        let trace = self.trace.get();
        if trace != 0 {
            self.recorder.record(
                trace,
                TraceEvent::FleetBatch {
                    worker: worker.to_string(),
                    patterns: patterns as u64,
                    wall_ns: wall.as_nanos() as u64,
                    outcome: outcome.to_string(),
                },
            );
        }
    }

    fn redeal(&self) {
        self.metrics
            .counter(
                "fbo_fleet_redeals_total",
                "Fleet batch re-deals after a worker death or timeout.",
                &[],
            )
            .inc();
    }

    fn reconnect(&self, worker: &str, attempt: u64, delay_ms: u64, ok: bool) {
        self.metrics
            .counter(
                "fbo_fleet_reconnects_total",
                "Fleet worker reconnection attempts by worker and outcome.",
                &[("worker", worker), ("outcome", if ok { "ok" } else { "error" })],
            )
            .inc();
        let trace = self.trace.get();
        if trace != 0 {
            self.recorder.record(
                trace,
                TraceEvent::FleetReconnect { worker: worker.to_string(), attempt, delay_ms, ok },
            );
        }
    }
}

/// A [`PatternExecutor`] that measures over the fleet, falling back to a
/// local executor whenever the fleet cannot answer. Owns the registry —
/// dropping the executor drains every worker.
pub struct FleetExecutor {
    registry: FleetRegistry,
    fallback: Rc<dyn PatternExecutor>,
    timeout: Duration,
    max_retries: u32,
    stats: FleetStats,
    telemetry: Option<FleetTelemetry>,
}

impl FleetExecutor {
    /// A fleet executor over `registry`, measuring locally on `fallback`
    /// whenever a pattern cannot (or should not) go remote.
    pub fn new(registry: FleetRegistry, fallback: Rc<dyn PatternExecutor>) -> FleetExecutor {
        FleetExecutor {
            registry,
            fallback,
            timeout: DEFAULT_BATCH_TIMEOUT,
            max_retries: DEFAULT_MAX_RETRIES,
            stats: FleetStats::default(),
            telemetry: None,
        }
    }

    /// Override the per-round batch deadline (tests shrink it to force
    /// the timeout path).
    pub fn with_timeout(mut self, timeout: Duration) -> FleetExecutor {
        self.timeout = timeout;
        self
    }

    /// Attach metrics + trace hooks.
    pub fn with_telemetry(mut self, telemetry: FleetTelemetry) -> FleetExecutor {
        self.telemetry = Some(telemetry);
        self
    }

    /// Scheduling counters accumulated so far.
    pub fn stats(&self) -> &FleetStats {
        &self.stats
    }

    /// The worker registry this executor deals over.
    pub fn registry(&self) -> &FleetRegistry {
        &self.registry
    }

    fn measure_local(
        &self,
        ctx: &VerifyContext<'_>,
        specs: &[PatternSpec],
        indices: &[usize],
        results: &mut [Option<Result<MeasuredPattern>>],
    ) {
        let subset: Vec<PatternSpec> = indices.iter().map(|&i| specs[i].clone()).collect();
        let outcomes = self.fallback.measure(ctx, &subset);
        FleetStats::bump(&self.stats.local, indices.len() as u64);
        for (&i, outcome) in indices.iter().zip(outcomes) {
            results[i] = Some(outcome);
        }
    }
}

impl PatternExecutor for FleetExecutor {
    fn measure(
        &self,
        ctx: &VerifyContext<'_>,
        specs: &[PatternSpec],
    ) -> Vec<Result<MeasuredPattern>> {
        let mut results: Vec<Option<Result<MeasuredPattern>>> =
            (0..specs.len()).map(|_| None).collect();
        // Revive dead TCP endpoints before dealing: each re-dial is
        // bounded and backoff-gated inside the registry, so a permanently
        // gone box costs a bounded, spread-out stall and then goes quiet.
        if self.registry.live_count() < self.registry.workers().len() {
            self.registry.reconnect_dead(|worker, attempt, delay_ms, ok| {
                eprintln!(
                    "fleet: reconnect attempt {attempt} to {worker} after {delay_ms}ms: {}",
                    if ok { "ok" } else { "failed" }
                );
                if let Some(t) = &self.telemetry {
                    t.reconnect(worker, attempt, delay_ms, ok);
                }
            });
        }
        if self.registry.live_count() == 0 {
            self.measure_local(ctx, specs, &(0..specs.len()).collect::<Vec<_>>(), &mut results);
            return unwrap_all(results);
        }
        if let Some(t) = &self.telemetry {
            t.workers(self.registry.live_count());
        }
        let source = parser::print_program(ctx.prog);
        let mut pending: Vec<usize> = (0..specs.len()).collect();
        let mut backoff =
            Backoff::new(REDEAL_BACKOFF_BASE, REDEAL_BACKOFF_CAP, fnv1a64(ctx.entry.as_bytes()));
        loop {
            let available: Vec<&FleetWorker> = self
                .registry
                .workers()
                .iter()
                .filter(|w| w.is_alive() && !w.is_busy())
                .collect();
            if available.is_empty() {
                self.measure_local(ctx, specs, &pending, &mut results);
                break;
            }
            let (deal, local) = deal_round(specs, &pending, &available, ctx.blocks, ctx.cost_hints);
            let mut inflight = Vec::new();
            for (wi, indices) in deal {
                let batch = WireBatch {
                    source: source.clone(),
                    entry: ctx.entry.to_string(),
                    blocks: ctx.blocks.to_vec(),
                    cfg: ctx.cfg.clone(),
                    specs: indices.iter().map(|&i| specs[i].clone()).collect(),
                };
                let id = self.registry.next_batch_id();
                let rx = available[wi].dispatch(id, batch);
                inflight.push((available[wi], indices, rx, Instant::now()));
            }
            // Patterns no capable worker can take measure locally while
            // the remote batches run.
            if !local.is_empty() {
                self.measure_local(ctx, specs, &local, &mut results);
            }
            let deadline = Instant::now() + self.timeout;
            let mut retry = Vec::new();
            for (worker, indices, rx, started) in inflight {
                let remaining = deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(remaining) {
                    Ok(Ok(outcomes)) => {
                        // The registry validated the alignment already.
                        for (&i, outcome) in indices.iter().zip(outcomes) {
                            results[i] = Some(outcome.into_result());
                        }
                        FleetStats::bump(&self.stats.remote, indices.len() as u64);
                        if let Some(t) = &self.telemetry {
                            t.batch(worker.name(), indices.len(), started.elapsed(), "ok");
                        }
                    }
                    Ok(Err(_)) | Err(RecvTimeoutError::Disconnected) => {
                        eprintln!("fleet: worker {} lost mid-batch, re-dealing", worker.name());
                        retry.extend(indices);
                        if let Some(t) = &self.telemetry {
                            t.batch(worker.name(), 0, started.elapsed(), "error");
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        // The connection thread keeps waiting and keeps
                        // the worker marked busy; a late reply merely
                        // clears the flag.
                        eprintln!(
                            "fleet: worker {} missed the {:?} batch deadline, re-dealing",
                            worker.name(),
                            self.timeout
                        );
                        retry.extend(indices);
                        if let Some(t) = &self.telemetry {
                            t.batch(worker.name(), 0, started.elapsed(), "timeout");
                        }
                    }
                }
            }
            if let Some(t) = &self.telemetry {
                t.workers(self.registry.live_count());
            }
            pending = retry;
            if pending.is_empty() {
                break;
            }
            FleetStats::bump(&self.stats.redeals, 1);
            if let Some(t) = &self.telemetry {
                t.redeal();
            }
            if backoff.attempts() >= self.max_retries || self.registry.live_count() == 0 {
                self.measure_local(ctx, specs, &pending, &mut results);
                break;
            }
            std::thread::sleep(backoff.next_delay());
        }
        unwrap_all(results)
    }

    fn name(&self) -> &'static str {
        "fleet"
    }
}

fn unwrap_all(results: Vec<Option<Result<MeasuredPattern>>>) -> Vec<Result<MeasuredPattern>> {
    results
        .into_iter()
        .map(|r| r.expect("every planned pattern resolves remotely or locally"))
        .collect()
}

/// The capability a pattern needs: the union of its enabled blocks'
/// target kinds.
fn needs(spec: &PatternSpec, blocks: &[PlannedReplacement]) -> (bool, bool) {
    let mut gpu = false;
    let mut fpga = false;
    for (block, &on) in blocks.iter().zip(&spec.enabled) {
        if on {
            match block.replacement.kind {
                TargetKind::GpuLibrary => gpu = true,
                TargetKind::FpgaIpCore => fpga = true,
            }
        }
    }
    (gpu, fpga)
}

fn capable(caps: &Capabilities, need: (bool, bool)) -> bool {
    (!need.0 || caps.gpu) && (!need.1 || caps.fpga)
}

/// Estimated relative cost of measuring a pattern: every block left on
/// the interpreter costs, so the all-CPU baseline is the most expensive
/// and the everything-offloaded pattern the cheapest. The absolute scale
/// is irrelevant — only the ordering feeds the deal.
///
/// With estimator `hints` (per-block predicted device wall seconds,
/// aligned with `blocks`), each offloaded block additionally contributes
/// its predicted seconds. Interpreter-resident blocks are weighted so
/// that one always outweighs the entire hint mass — the hints refine the
/// ordering *within* the same interpreter burden, never against it. With
/// empty hints this reduces to exactly `disabled + 1`, the pre-estimator
/// integer formula, so unhinted fleets deal identically to before.
fn cost(spec: &PatternSpec, blocks: &[PlannedReplacement], hints: &[f64]) -> f64 {
    let scale: f64 = hints.iter().sum::<f64>() + 1.0;
    let mut c = scale;
    for (i, &on) in spec.enabled.iter().enumerate().take(blocks.len()) {
        if on {
            c += hints.get(i).copied().unwrap_or(0.0);
        } else {
            c += scale;
        }
    }
    c
}

/// Deal `pending` across `workers` greedily by descending cost (LPT):
/// each pattern lands on the capable worker with the least accumulated
/// cost. Patterns with no capable worker land in the local list. Both
/// the order sort and the tie-breaks are deterministic: descending cost
/// with the spec index breaking ties, and the lowest-indexed least-loaded
/// worker winning each pick.
fn deal_round(
    specs: &[PatternSpec],
    pending: &[usize],
    workers: &[&FleetWorker],
    blocks: &[PlannedReplacement],
    hints: &[f64],
) -> (Vec<(usize, Vec<usize>)>, Vec<usize>) {
    let mut order: Vec<usize> = pending.to_vec();
    order.sort_by(|&a, &b| {
        cost(&specs[b], blocks, hints).total_cmp(&cost(&specs[a], blocks, hints)).then(a.cmp(&b))
    });
    let mut loads: Vec<f64> = vec![0.0; workers.len()];
    let mut assigned: Vec<Vec<usize>> = vec![Vec::new(); workers.len()];
    let mut local = Vec::new();
    for i in order {
        let need = needs(&specs[i], blocks);
        let pick = (0..workers.len())
            .filter(|&w| capable(workers[w].caps(), need))
            .min_by(|&a, &b| loads[a].total_cmp(&loads[b]).then(a.cmp(&b)));
        match pick {
            Some(w) => {
                loads[w] += cost(&specs[i], blocks, hints);
                assigned[w].push(i);
            }
            None => local.push(i),
        }
    }
    // Batch order must follow spec order so outcomes map back by zip.
    let deal = assigned
        .into_iter()
        .enumerate()
        .filter(|(_, idx)| !idx.is_empty())
        .map(|(w, mut idx)| {
            idx.sort_unstable();
            (w, idx)
        })
        .collect();
    local.sort_unstable();
    (deal, local)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::patterndb::{Replacement, Signature, TargetKind};
    use crate::transform::{Reconciliation, Site};

    fn block(kind: TargetKind) -> PlannedReplacement {
        PlannedReplacement {
            site: Site::LibraryCall { callee: "fft".to_string() },
            replacement: Replacement {
                name: "fft".to_string(),
                kind,
                artifact: "fft".to_string(),
                signature: Signature::new(&[("a", "float[]")], "float[]"),
                usage: String::new(),
                opencl_code: None,
                pass_model: None,
                description: String::new(),
            },
            reconciliation: Reconciliation::Exact,
        }
    }

    fn spec(enabled: Vec<bool>) -> PatternSpec {
        let label = format!("spec-{enabled:?}");
        PatternSpec { enabled, label }
    }

    #[test]
    fn needs_unions_enabled_block_kinds() {
        let blocks = vec![block(TargetKind::GpuLibrary), block(TargetKind::FpgaIpCore)];
        assert_eq!(needs(&spec(vec![false, false]), &blocks), (false, false));
        assert_eq!(needs(&spec(vec![true, false]), &blocks), (true, false));
        assert_eq!(needs(&spec(vec![false, true]), &blocks), (false, true));
        assert_eq!(needs(&spec(vec![true, true]), &blocks), (true, true));
    }

    #[test]
    fn capability_covering_is_per_need() {
        let gpu_only = Capabilities { gpu: true, fpga: false, ..Capabilities::default() };
        assert!(capable(&gpu_only, (false, false)), "baseline runs anywhere");
        assert!(capable(&gpu_only, (true, false)));
        assert!(!capable(&gpu_only, (false, true)));
        assert!(!capable(&gpu_only, (true, true)));
    }

    #[test]
    fn cost_ranks_the_baseline_most_expensive() {
        let blocks = vec![block(TargetKind::GpuLibrary), block(TargetKind::GpuLibrary)];
        let baseline = cost(&spec(vec![false, false]), &blocks, &[]);
        let one = cost(&spec(vec![true, false]), &blocks, &[]);
        let both = cost(&spec(vec![true, true]), &blocks, &[]);
        assert!(baseline > one, "{baseline} vs {one}");
        assert!(one > both, "{one} vs {both}");
    }

    #[test]
    fn unhinted_cost_reproduces_the_integer_formula() {
        let blocks = vec![
            block(TargetKind::GpuLibrary),
            block(TargetKind::FpgaIpCore),
            block(TargetKind::GpuLibrary),
        ];
        for enabled in [
            vec![false, false, false],
            vec![true, false, true],
            vec![true, true, true],
        ] {
            let on = enabled.iter().filter(|&&b| b).count() as u64;
            let expected = blocks.len() as u64 + 1 - on;
            assert_eq!(cost(&spec(enabled), &blocks, &[]), expected as f64);
        }
    }

    #[test]
    fn hints_refine_but_never_outrank_interpreter_burden() {
        let blocks = vec![block(TargetKind::GpuLibrary), block(TargetKind::GpuLibrary)];
        // Second block predicted much slower on the device than the first.
        let hints = [0.001, 0.9];
        let baseline = cost(&spec(vec![false, false]), &blocks, &hints);
        let slow = cost(&spec(vec![false, true]), &blocks, &hints);
        let fast = cost(&spec(vec![true, false]), &blocks, &hints);
        let both = cost(&spec(vec![true, true]), &blocks, &hints);
        // Same interpreter burden: the hint decides the order.
        assert!(slow > fast, "{slow} vs {fast}");
        // Different interpreter burden: the hint never flips it.
        assert!(baseline > slow, "{baseline} vs {slow}");
        assert!(fast > both, "{fast} vs {both}");
    }

    /// All 2^n patterns over `blocks`, labeled like the verify planner.
    fn sweep(n: usize) -> Vec<PatternSpec> {
        (0..1usize << n)
            .map(|bits| spec((0..n).map(|b| bits >> b & 1 == 1).collect()))
            .collect()
    }

    fn stub_fleet() -> Vec<FleetWorker> {
        vec![
            FleetWorker::stub("gpu-0", Capabilities { gpu: true, fpga: false, ..Capabilities::default() }),
            FleetWorker::stub("fpga-0", Capabilities { gpu: false, fpga: true, ..Capabilities::default() }),
            FleetWorker::stub("both-0", Capabilities { gpu: true, fpga: true, ..Capabilities::default() }),
        ]
    }

    /// Satellite property: the LPT deal is a pure function of the pending
    /// *set* — any permutation of the pending order produces the identical
    /// partition, because ordering is (cost, index) and the worker pick is
    /// (load, index), both total.
    #[test]
    fn deal_is_deterministic_under_pending_permutation() {
        let blocks = vec![
            block(TargetKind::GpuLibrary),
            block(TargetKind::FpgaIpCore),
            block(TargetKind::GpuLibrary),
        ];
        let specs = sweep(blocks.len());
        let owned = stub_fleet();
        let workers: Vec<&FleetWorker> = owned.iter().collect();
        for hints in [&[][..], &[0.25, 0.5, 0.125][..]] {
            let canonical: Vec<usize> = (0..specs.len()).collect();
            let baseline = deal_round(&specs, &canonical, &workers, &blocks, hints);
            // Deterministic permutations: reversal, odd/even interleave,
            // and every rotation of the canonical order.
            let mut perms: Vec<Vec<usize>> = vec![canonical.iter().rev().copied().collect()];
            perms.push(
                canonical.iter().step_by(2).chain(canonical.iter().skip(1).step_by(2)).copied().collect(),
            );
            for r in 1..canonical.len() {
                let mut rot = canonical.clone();
                rot.rotate_left(r);
                perms.push(rot);
            }
            for perm in perms {
                let dealt = deal_round(&specs, &perm, &workers, &blocks, hints);
                assert_eq!(dealt, baseline, "permutation {perm:?} changed the deal");
            }
        }
    }

    /// Satellite property: no pattern is ever dealt to a worker whose
    /// capabilities do not cover its need, whatever the hint vector, and
    /// patterns nobody covers land in the local list exactly once.
    #[test]
    fn deal_never_hands_a_pattern_to_an_incapable_worker() {
        let blocks = vec![
            block(TargetKind::GpuLibrary),
            block(TargetKind::FpgaIpCore),
            block(TargetKind::FpgaIpCore),
        ];
        let specs = sweep(blocks.len());
        let pending: Vec<usize> = (0..specs.len()).collect();
        // Fleets of every capability mix, including one with no FPGA box
        // (FPGA-needing patterns must then fall back to the local list).
        let cpu_only =
            vec![FleetWorker::stub("cpu-0", Capabilities { gpu: false, fpga: false, ..Capabilities::default() })];
        let gpu_only =
            vec![FleetWorker::stub("gpu-0", Capabilities { gpu: true, fpga: false, ..Capabilities::default() })];
        for owned in [stub_fleet(), gpu_only, cpu_only] {
            let workers: Vec<&FleetWorker> = owned.iter().collect();
            for hints in [&[][..], &[0.75, 0.0625, 0.333][..]] {
                let (deal, local) = deal_round(&specs, &pending, &workers, &blocks, hints);
                let mut seen = vec![0usize; specs.len()];
                for (w, indices) in &deal {
                    for &i in indices {
                        seen[i] += 1;
                        assert!(
                            capable(workers[*w].caps(), needs(&specs[i], &blocks)),
                            "pattern {} dealt to incapable worker {}",
                            specs[i].label,
                            workers[*w].name()
                        );
                    }
                }
                for &i in &local {
                    seen[i] += 1;
                    assert!(
                        !workers.iter().any(|w| capable(w.caps(), needs(&specs[i], &blocks))),
                        "pattern {} went local despite a capable worker",
                        specs[i].label
                    );
                }
                assert_eq!(seen, vec![1; specs.len()], "every pattern dealt exactly once");
            }
        }
    }
}
