//! PJRT runtime: load and execute the AOT function-block artifacts.
//!
//! This is the only bridge to the compiled L1/L2 world: `make artifacts`
//! lowers the JAX/Pallas function blocks to HLO **text** (xla_extension
//! 0.5.1 rejects jax>=0.5 serialized protos — 64-bit instruction ids; the
//! text parser reassigns them), and this module compiles each artifact once
//! on the PJRT CPU client and executes it from the coordinator's hot path.
//! Python never runs here.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::patterndb::json::{self, Json};

pub mod data_plane;

pub use data_plane::{BufferHandle, DataPlane, ResidencyStats};

/// Shape+dtype of one artifact input/output (dtype is always f32 at this
/// boundary; complex data travels as split re/im planes).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Dimensions, outermost first.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Manifest entry for one artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Artifact name (`{block}_n{size}`).
    pub name: String,
    /// HLO text file name within the artifact dir.
    pub file: String,
    /// Human-readable description from the manifest.
    pub description: String,
    /// Input tensor shapes, in dispatch order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor shapes, in result order.
    pub outputs: Vec<TensorSpec>,
}

/// A compiled, executable artifact.
pub struct LoadedArtifact {
    /// Manifest entry the artifact was compiled from.
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// Execution statistics (dispatches + bytes through the PJRT boundary).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    /// Artifact dispatches executed.
    pub executions: u64,
    /// Bytes staged host -> device across all dispatches.
    pub bytes_in: u64,
    /// Bytes read device -> host across all dispatches.
    pub bytes_out: u64,
    /// Artifacts compiled (first dispatch of each; cached after).
    pub compiles: u64,
    /// Host -> device bytes whose transfer was elided because the value was
    /// already resident on the device (zero unless a [`DataPlane`] is
    /// installed). Not included in `bytes_in`, which stays paid-only.
    pub elided_in: u64,
    /// Device -> host bytes elided by residency (zero unless a [`DataPlane`]
    /// is installed). Not included in `bytes_out`.
    pub elided_out: u64,
    /// Wall-clock seconds spent inside [`Engine::execute`] after the
    /// artifact lookup: host staging + device execution + readback. This is
    /// the measured "GPU time" of the PJRT-as-GPU substitution; the
    /// backend-arbitration stage compares FPGA estimates against it.
    pub exec_secs: f64,
}

/// The runtime engine: one PJRT CPU client + lazily compiled artifacts.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    metas: HashMap<String, ArtifactMeta>,
    compiled: RefCell<HashMap<String, Rc<LoadedArtifact>>>,
    /// Execution statistics (dispatches, bytes, measured seconds).
    pub stats: RefCell<EngineStats>,
    plane: RefCell<Option<Rc<DataPlane>>>,
}

impl Engine {
    /// Open an artifact directory (reads `manifest.json`; compiles lazily).
    pub fn open(dir: &Path) -> Result<Rc<Self>> {
        let manifest_path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let v = json::parse(&src)?;
        if v.get("format")?.as_str()? != "hlo-text" {
            bail!("unsupported artifact format");
        }
        let mut metas = HashMap::new();
        for a in v.get("artifacts")?.as_arr()? {
            let meta = ArtifactMeta {
                name: a.get("name")?.as_str()?.to_string(),
                file: a.get("file")?.as_str()?.to_string(),
                description: a
                    .opt("description")
                    .and_then(|d| d.as_str().ok())
                    .unwrap_or("")
                    .to_string(),
                inputs: parse_specs(a.get("inputs")?)?,
                outputs: parse_specs(a.get("outputs")?)?,
            };
            metas.insert(meta.name.clone(), meta);
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Rc::new(Engine {
            client,
            dir: dir.to_path_buf(),
            metas,
            compiled: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
            plane: RefCell::new(None),
        }))
    }

    /// Install a device-resident data plane. Every subsequent
    /// [`Engine::execute`] classifies its transfers as paid or elided
    /// against the plane's residency map; the plane persists across
    /// requests (hot inputs stay resident in the worker pool) until
    /// replaced. No plane is installed by default, in which case byte
    /// accounting is identical to a build without residency.
    pub fn install_data_plane(&self, plane: Rc<DataPlane>) {
        *self.plane.borrow_mut() = Some(plane);
    }

    /// The installed data plane, if any.
    pub fn data_plane(&self) -> Option<Rc<DataPlane>> {
        self.plane.borrow().clone()
    }

    /// Remove the data plane, returning byte accounting to the exact
    /// pre-residency arithmetic. A later `--resident-bytes 0` request on
    /// an engine warmed by a resident one must observe byte-identical
    /// traffic to a fresh engine.
    pub fn uninstall_data_plane(&self) {
        *self.plane.borrow_mut() = None;
    }

    /// Artifact names available in the manifest.
    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.metas.keys().cloned().collect();
        v.sort();
        v
    }

    /// Is an artifact with this name in the manifest?
    pub fn has_artifact(&self, name: &str) -> bool {
        self.metas.contains_key(name)
    }

    /// Manifest entry for an artifact, if present.
    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.metas.get(name)
    }

    /// Compile (once) and return an artifact.
    pub fn artifact(&self, name: &str) -> Result<Rc<LoadedArtifact>> {
        if let Some(a) = self.compiled.borrow().get(name) {
            return Ok(a.clone());
        }
        let meta = self
            .metas
            .get(name)
            .ok_or_else(|| {
                anyhow!("no artifact {name:?} in manifest (have: {:?})", self.artifact_names())
            })?
            .clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        self.stats.borrow_mut().compiles += 1;
        let loaded = Rc::new(LoadedArtifact { meta, exe });
        self.compiled.borrow_mut().insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Execute an artifact on f32 buffers. Input/output order follows the
    /// manifest. Shapes are validated against the manifest specs.
    pub fn execute(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let art = self.artifact(name)?;
        // Timed from here (compile excluded): staging + execute + readback.
        let t0 = std::time::Instant::now();
        if inputs.len() != art.meta.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                art.meta.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, spec) in inputs.iter().zip(&art.meta.inputs) {
            if buf.len() != spec.elems() {
                bail!(
                    "{name}: input length {} does not match shape {:?}",
                    buf.len(),
                    spec.shape
                );
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape to {dims:?}: {e}"))?;
            literals.push(lit);
        }
        {
            let plane = self.plane.borrow();
            let mut st = self.stats.borrow_mut();
            st.executions += 1;
            match plane.as_deref() {
                None => {
                    st.bytes_in += inputs.iter().map(|b| (b.len() * 4) as u64).sum::<u64>();
                }
                Some(p) => {
                    for buf in inputs {
                        let h = BufferHandle::of_f32(buf);
                        if p.stage_in(&h) {
                            st.elided_in += h.bytes;
                        } else {
                            st.bytes_in += h.bytes;
                        }
                    }
                }
            }
        }
        let result = art
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e}"))?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of {name}: {e}"))?;
        if parts.len() != art.meta.outputs.len() {
            bail!(
                "{name}: expected {} outputs, got {}",
                art.meta.outputs.len(),
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (p, spec) in parts.into_iter().zip(&art.meta.outputs) {
            let v = p
                .to_vec::<f32>()
                .map_err(|e| anyhow!("reading output of {name}: {e}"))?;
            if v.len() != spec.elems() {
                bail!("{name}: output length {} != shape {:?}", v.len(), spec.shape);
            }
            match self.plane.borrow().as_deref() {
                None => self.stats.borrow_mut().bytes_out += (v.len() * 4) as u64,
                Some(p) => {
                    let h = BufferHandle::of_f32(&v);
                    let mut st = self.stats.borrow_mut();
                    if p.read_back(&h) {
                        st.elided_out += h.bytes;
                    } else {
                        st.bytes_out += h.bytes;
                    }
                }
            }
            out.push(v);
        }
        self.stats.borrow_mut().exec_secs += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    /// Pick the size variant of a block artifact: `"{base}_n{n}"`.
    pub fn sized_artifact_name(&self, base: &str, n: usize) -> Result<String> {
        let name = format!("{base}_n{n}");
        if self.has_artifact(&name) {
            Ok(name)
        } else {
            bail!(
                "no artifact for block {base:?} at size {n} (have: {:?}); \
                 re-run `make artifacts` with --sizes including {n}",
                self.artifact_names()
            )
        }
    }
}

fn parse_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    let mut out = Vec::new();
    for t in v.as_arr()? {
        let shape = t
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>>>()?;
        out.push(TensorSpec { shape });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn engine() -> Rc<Engine> {
        Engine::open(&artifacts_dir()).expect("run `make artifacts` before cargo test")
    }

    #[test]
    fn manifest_loads_with_expected_artifacts() {
        let e = engine();
        for name in ["fft2d_n64", "lu_factor_n64", "matmul_n64", "lu_solve_n64"] {
            assert!(e.has_artifact(name), "missing {name}");
        }
    }

    #[test]
    fn matmul_artifact_is_numerically_correct() {
        let e = engine();
        let n = 64;
        // a = I scaled by 2, b = ramp; a@b = 2*ramp.
        let mut a = vec![0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 2.0;
        }
        let b: Vec<f32> = (0..n * n).map(|i| (i % 97) as f32).collect();
        let out = e.execute("matmul_n64", &[a, b.clone()]).unwrap();
        assert_eq!(out.len(), 1);
        for (got, want) in out[0].iter().zip(b.iter().map(|v| v * 2.0)) {
            assert!((got - want).abs() < 1e-3, "{got} vs {want}");
        }
    }

    #[test]
    fn fft_artifact_impulse_is_flat() {
        let e = engine();
        let n = 64;
        let mut re = vec![0f32; n * n];
        re[0] = 1.0;
        let im = vec![0f32; n * n];
        let out = e.execute("fft2d_n64", &[re, im]).unwrap();
        assert_eq!(out.len(), 2);
        for v in &out[0] {
            assert!((v - 1.0).abs() < 1e-3);
        }
        for v in &out[1] {
            assert!(v.abs() < 1e-3);
        }
    }

    #[test]
    fn lu_artifact_factors_identity() {
        let e = engine();
        let n = 64;
        let mut a = vec![0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let out = e.execute("lu_factor_n64", &[a.clone()]).unwrap();
        for (got, want) in out[0].iter().zip(&a) {
            assert!((got - want).abs() < 1e-4);
        }
    }

    #[test]
    fn shape_validation_errors() {
        let e = engine();
        assert!(e.execute("matmul_n64", &[vec![0f32; 3], vec![0f32; 3]]).is_err());
        assert!(e.execute("matmul_n64", &[vec![0f32; 64 * 64]]).is_err());
        assert!(e.execute("nonexistent", &[]).is_err());
    }

    #[test]
    fn sized_artifact_lookup() {
        let e = engine();
        assert_eq!(e.sized_artifact_name("fft2d", 64).unwrap(), "fft2d_n64");
        assert!(e.sized_artifact_name("fft2d", 99).is_err());
    }

    #[test]
    fn stats_accumulate() {
        let e = engine();
        let n = 64;
        let a = vec![1f32; n * n];
        e.execute("matmul_n64", &[a.clone(), a.clone()]).unwrap();
        e.execute("matmul_n64", &[a.clone(), a]).unwrap();
        let st = e.stats.borrow();
        assert_eq!(st.executions, 2);
        assert_eq!(st.compiles, 1); // compiled once, cached after
        assert!(st.bytes_in > 0 && st.bytes_out > 0);
        assert!(st.exec_secs > 0.0, "dispatch wall-clock must accumulate");
        assert_eq!(st.elided_in, 0, "no plane installed -> nothing elided");
        assert_eq!(st.elided_out, 0);
    }

    #[test]
    fn installed_plane_splits_paid_and_elided_bytes() {
        let e = engine();
        let n = 64;
        let a = vec![1f32; n * n];
        let buf_bytes = (n * n * 4) as u64;
        e.install_data_plane(Rc::new(DataPlane::new(64 << 20)));
        // First dispatch pays both inputs (identical buffers share one
        // handle: the second operand of the same dispatch is already
        // resident once the first is staged).
        e.execute("matmul_n64", &[a.clone(), a.clone()]).unwrap();
        let first = e.stats.borrow().clone();
        assert_eq!(first.bytes_in, buf_bytes, "one paid staging of the shared value");
        assert_eq!(first.elided_in, buf_bytes, "duplicate operand elided");
        // Second identical dispatch: inputs fully resident, nothing paid in.
        e.execute("matmul_n64", &[a.clone(), a]).unwrap();
        let second = e.stats.borrow().clone();
        assert_eq!(second.bytes_in, first.bytes_in, "warm inputs pay nothing");
        assert_eq!(second.elided_in, first.elided_in + 2 * buf_bytes);
        // The repeated output is elided on the second readback.
        assert_eq!(second.bytes_out, first.bytes_out);
        assert!(second.elided_out > first.elided_out);
        let plane = e.data_plane().expect("plane installed");
        let s = plane.stats();
        assert!(s.hits >= 3 && s.resident_bytes > 0);
    }
}
