//! Device-resident data plane: buffer residency, pinning, and LRU spill.
//!
//! Both FPGA exemplars the pattern DB models win by *not* round-tripping
//! data — one reuses matrices persisted in BRAM across calls, the other
//! keeps an OpenCL buffer pool with a free-index queue. This module is
//! the runtime-side version of that idea: a [`DataPlane`] tracks which
//! values currently live on the device (by content hash), so adjacent
//! offloaded blocks can hand tensors to each other without a host
//! readback and hot pattern inputs stay resident across service
//! requests.
//!
//! The plane is an *accounting* model, the same substitution discipline
//! as the simulated HLS chain (DESIGN.md "Substitutions"): execution
//! still physically copies buffers through the PJRT boundary, but every
//! transfer is classified as **paid** (the value was not resident) or
//! **elided** (it was). The verify stage splits its observed
//! [`crate::coordinator::verify::DeviceTraffic`] along exactly this
//! line, and arbitration credits the elided bytes with the same PCIe
//! arithmetic the power model already prices.
//!
//! Residency is bounded by a byte budget (`--resident-bytes`): admitting
//! a value over budget spills least-recently-used unpinned entries
//! first; pinned entries never spill; a value larger than the whole
//! budget is never admitted and pays its transfer every time. A budget
//! of zero disables the plane entirely — the pipeline then never
//! installs one, keeping the default path byte-identical to a build
//! without it.

use std::cell::RefCell;
use std::collections::HashMap;

/// Typed handle to one tensor value: a content hash plus its size. Two
/// buffers with identical bit patterns get identical handles — which is
/// precisely what inter-block handoff needs (block B consumes the bytes
/// block A produced).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BufferHandle {
    /// FNV-1a content hash of the buffer's bit pattern.
    pub hash: u64,
    /// Buffer size in bytes (as staged over PCIe).
    pub bytes: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

impl BufferHandle {
    /// Handle of an f32 buffer (the PJRT artifact boundary).
    pub fn of_f32(data: &[f32]) -> BufferHandle {
        let mut h = FNV_OFFSET;
        for v in data {
            h = fnv1a(h, &v.to_bits().to_le_bytes());
        }
        BufferHandle { hash: h, bytes: (data.len() * 4) as u64 }
    }

    /// Handle of an f64 buffer (the bulk loop-offload executor).
    pub fn of_f64(data: &[f64]) -> BufferHandle {
        let mut h = FNV_OFFSET;
        for v in data {
            h = fnv1a(h, &v.to_bits().to_le_bytes());
        }
        BufferHandle { hash: h, bytes: (data.len() * 8) as u64 }
    }
}

/// Counters of one plane's lifetime (cumulative; never reset by spills).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Touches that found the value resident (transfer elided).
    pub hits: u64,
    /// Touches that had to pay the transfer.
    pub misses: u64,
    /// Entries evicted to make room under the budget.
    pub spills: u64,
    /// Bytes currently resident on the device.
    pub resident_bytes: u64,
    /// Bytes currently pinned (subset of `resident_bytes`).
    pub pinned_bytes: u64,
}

#[derive(Debug, Clone)]
struct Entry {
    bytes: u64,
    pinned: bool,
    tick: u64,
}

#[derive(Debug, Default)]
struct PlaneState {
    entries: HashMap<u64, Entry>,
    used: u64,
    tick: u64,
    hits: u64,
    misses: u64,
    spills: u64,
}

/// The residency map of one engine: which values live on the device,
/// under a byte budget, with LRU spill and pinning. Single-threaded by
/// design (the PJRT runtime is `Rc`/`RefCell` state per worker thread);
/// share it via `Rc`.
#[derive(Debug)]
pub struct DataPlane {
    budget: u64,
    state: RefCell<PlaneState>,
}

impl DataPlane {
    /// Plane with a byte budget. A zero budget admits nothing — callers
    /// gate on the budget and skip installing a plane at all.
    pub fn new(budget_bytes: u64) -> DataPlane {
        DataPlane { budget: budget_bytes, state: RefCell::new(PlaneState::default()) }
    }

    /// The byte budget this plane spills under.
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Touch a value on its way host → device. Returns `true` when the
    /// value is already resident (the transfer is elided); otherwise the
    /// value is admitted — spilling LRU unpinned entries while over
    /// budget — and the transfer is paid (`false`).
    pub fn stage_in(&self, h: &BufferHandle) -> bool {
        self.touch(h)
    }

    /// Touch a value on its way device → host. Same semantics as
    /// [`DataPlane::stage_in`]: a value just produced on the device
    /// becomes resident (its first readback is paid), so a later
    /// consumer's `stage_in` of the same bytes elides the round trip —
    /// the inter-block handoff.
    pub fn read_back(&self, h: &BufferHandle) -> bool {
        self.touch(h)
    }

    fn touch(&self, h: &BufferHandle) -> bool {
        let mut st = self.state.borrow_mut();
        st.tick += 1;
        let tick = st.tick;
        if let Some(e) = st.entries.get_mut(&h.hash) {
            e.tick = tick;
            st.hits += 1;
            return true;
        }
        st.misses += 1;
        if h.bytes > self.budget {
            // Oversized for the whole budget: never admitted, pays
            // every time.
            return false;
        }
        // Spill LRU unpinned entries until the value fits.
        while st.used + h.bytes > self.budget {
            let victim = st
                .entries
                .iter()
                .filter(|(_, e)| !e.pinned)
                .min_by_key(|(_, e)| e.tick)
                .map(|(&k, _)| k);
            match victim {
                Some(k) => {
                    let e = st.entries.remove(&k).expect("victim exists");
                    st.used -= e.bytes;
                    st.spills += 1;
                }
                None => return false, // everything resident is pinned
            }
        }
        st.used += h.bytes;
        st.entries.insert(h.hash, Entry { bytes: h.bytes, pinned: false, tick });
        false
    }

    /// Pin a resident value: it never spills until unpinned. A value not
    /// currently resident is ignored (pin after a successful admit).
    pub fn pin(&self, h: &BufferHandle) {
        if let Some(e) = self.state.borrow_mut().entries.get_mut(&h.hash) {
            e.pinned = true;
        }
    }

    /// Unpin a value, making it spillable again.
    pub fn unpin(&self, h: &BufferHandle) {
        if let Some(e) = self.state.borrow_mut().entries.get_mut(&h.hash) {
            e.pinned = false;
        }
    }

    /// Is this value currently resident on the device?
    pub fn is_resident(&self, h: &BufferHandle) -> bool {
        self.state.borrow().entries.contains_key(&h.hash)
    }

    /// Drop every entry (pinned included) and reset the used-bytes
    /// counter. Lifetime counters (hits/misses/spills) are kept.
    pub fn clear(&self) {
        let mut st = self.state.borrow_mut();
        st.entries.clear();
        st.used = 0;
    }

    /// Snapshot the plane's counters.
    pub fn stats(&self) -> ResidencyStats {
        let st = self.state.borrow();
        ResidencyStats {
            hits: st.hits,
            misses: st.misses,
            spills: st.spills,
            resident_bytes: st.used,
            pinned_bytes: st.entries.values().filter(|e| e.pinned).map(|e| e.bytes).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn handle(seed: f32, elems: usize) -> BufferHandle {
        BufferHandle::of_f32(&vec![seed; elems])
    }

    #[test]
    fn handles_are_content_addressed() {
        let a = BufferHandle::of_f32(&[1.0, 2.0, 3.0]);
        let b = BufferHandle::of_f32(&[1.0, 2.0, 3.0]);
        let c = BufferHandle::of_f32(&[1.0, 2.0, 4.0]);
        assert_eq!(a, b, "identical bits -> identical handle");
        assert_ne!(a.hash, c.hash);
        assert_eq!(a.bytes, 12);
        // f64 handles size by 8 bytes per element and hash the f64 bits.
        let d = BufferHandle::of_f64(&[1.0, 2.0, 3.0]);
        assert_eq!(d.bytes, 24);
        assert_ne!(d.hash, a.hash);
    }

    #[test]
    fn second_touch_is_a_hit() {
        let plane = DataPlane::new(1 << 20);
        let h = handle(1.0, 16);
        assert!(!plane.stage_in(&h), "first touch pays");
        assert!(plane.stage_in(&h), "second touch is elided");
        assert!(plane.read_back(&h), "direction does not matter");
        let s = plane.stats();
        assert_eq!((s.hits, s.misses), (2, 1));
        assert_eq!(s.resident_bytes, 64);
    }

    #[test]
    fn handoff_between_blocks_elides_the_second_transfer() {
        // Block A reads back its output; block B stages the same bytes in.
        let plane = DataPlane::new(1 << 20);
        let out = handle(7.0, 32);
        assert!(!plane.read_back(&out), "first readback is paid");
        assert!(plane.stage_in(&out), "consumer's staging is elided");
    }

    #[test]
    fn lru_spills_under_budget() {
        // Budget fits two 64-byte entries; a third spills the LRU one.
        let plane = DataPlane::new(128);
        let (a, b, c) = (handle(1.0, 16), handle(2.0, 16), handle(3.0, 16));
        plane.stage_in(&a);
        plane.stage_in(&b);
        plane.stage_in(&a); // a is now more recent than b
        assert!(!plane.stage_in(&c), "admitting c pays");
        assert!(!plane.is_resident(&b), "b was LRU and spilled");
        assert!(plane.is_resident(&a) && plane.is_resident(&c));
        let s = plane.stats();
        assert_eq!(s.spills, 1);
        assert_eq!(s.resident_bytes, 128);
    }

    #[test]
    fn pinned_entries_never_spill() {
        let plane = DataPlane::new(128);
        let (a, b, c) = (handle(1.0, 16), handle(2.0, 16), handle(3.0, 16));
        plane.stage_in(&a);
        plane.pin(&a);
        plane.stage_in(&b);
        plane.stage_in(&c); // must spill b (LRU among unpinned), not a
        assert!(plane.is_resident(&a), "pinned survives");
        assert!(!plane.is_resident(&b));
        assert_eq!(plane.stats().pinned_bytes, 64);
        // Unpinning makes it spillable again.
        plane.unpin(&a);
        let d = handle(4.0, 16);
        plane.stage_in(&d);
        assert!(!plane.is_resident(&a), "unpinned LRU spills");
    }

    #[test]
    fn oversized_values_are_never_admitted() {
        let plane = DataPlane::new(64);
        let big = handle(1.0, 32); // 128 bytes > 64 budget
        assert!(!plane.stage_in(&big));
        assert!(!plane.stage_in(&big), "pays every time");
        assert!(!plane.is_resident(&big));
        assert_eq!(plane.stats().resident_bytes, 0);
    }

    #[test]
    fn all_pinned_blocks_admission_without_panicking() {
        let plane = DataPlane::new(64);
        let a = handle(1.0, 16);
        plane.stage_in(&a);
        plane.pin(&a);
        let b = handle(2.0, 16);
        assert!(!plane.stage_in(&b), "no unpinned victim -> not admitted");
        assert!(plane.is_resident(&a) && !plane.is_resident(&b));
    }

    #[test]
    fn clear_drops_entries_but_keeps_lifetime_counters() {
        let plane = DataPlane::new(1 << 20);
        let h = handle(1.0, 16);
        plane.stage_in(&h);
        plane.stage_in(&h);
        plane.clear();
        assert!(!plane.is_resident(&h));
        assert_eq!(plane.stats().resident_bytes, 0);
        assert_eq!(plane.stats().hits, 1, "counters survive clear");
        assert!(!plane.stage_in(&h), "cleared value pays again");
    }
}
