//! FPGA offload substrate: simulated HLS toolchain + device model.
//!
//! The paper's FPGA path (Intel PAC Arria10 GX + Intel Acceleration Stack)
//! has two defining constraints our flow must reproduce (DESIGN.md
//! "Substitutions"):
//!
//! 1. **compiles take hours** (≈3 h even for a 100-line kernel), so
//!    candidates are narrowed *before* compiling — by arithmetic intensity
//!    and by a fast resource pre-check that "errors early when the resource
//!    amount overflows" (paper §4.1);
//! 2. **resources are finite** (ALMs / DSPs / M20K BRAMs), so each kernel
//!    gets a static resource estimate, checked against the device.
//!
//! Everything runs against a [`VirtualClock`] so tests and the ablation
//! bench can account simulated engineering hours without waiting for them.

use std::cell::Cell;

use anyhow::{bail, Result};

use crate::analysis::IntensityReport;

/// FPGA device resource envelope.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Device {
    /// Device name (e.g. "Intel Arria10 GX 1150").
    pub name: &'static str,
    /// Adaptive logic modules available.
    pub alms: u64,
    /// DSP blocks available.
    pub dsps: u64,
    /// M20K BRAM blocks available.
    pub m20ks: u64,
    /// Achievable pipeline clock (Hz).
    pub fmax: f64,
}

/// Intel Arria 10 GX 1150 (the paper's Intel PAC card).
pub const ARRIA10_GX: Device = Device {
    name: "Intel Arria10 GX 1150",
    alms: 427_200,
    dsps: 1_518,
    m20ks: 2_713,
    fmax: 240.0e6,
};

/// Static resource estimate of one kernel.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResourceEstimate {
    /// Adaptive logic modules required.
    pub alms: u64,
    /// DSP blocks required.
    pub dsps: u64,
    /// M20K BRAM blocks required.
    pub m20ks: u64,
}

impl ResourceEstimate {
    /// True when every resource dimension fits the device.
    pub fn fits(&self, dev: &Device) -> bool {
        self.alms <= dev.alms && self.dsps <= dev.dsps && self.m20ks <= dev.m20ks
    }

    /// Utilization fraction of the scarcest resource.
    pub fn utilization(&self, dev: &Device) -> f64 {
        let a = self.alms as f64 / dev.alms as f64;
        let d = self.dsps as f64 / dev.dsps as f64;
        let m = self.m20ks as f64 / dev.m20ks as f64;
        a.max(d).max(m)
    }
}

/// Estimate resources for a loop kernel from its intensity report.
/// Rough HLS heuristics: one DSP per multiplier (f64 ≈ 4 DSP), ALMs for
/// control + adders, M20Ks for the working set held in local memory.
pub fn estimate_loop_resources(r: &IntensityReport, unroll: u64) -> ResourceEstimate {
    let flops = r.flops_per_iter.max(1) * unroll;
    let mem = r.mem_per_iter.max(1) * unroll;
    ResourceEstimate {
        dsps: flops * 4,
        alms: 500 + flops * 320 + mem * 150,
        // Each M20K is 2.5 KB; assume double-buffered f64 working set of
        // 1024 elements per memory port.
        m20ks: mem * 8,
    }
}

/// Estimate for a DB-registered IP core (paper: IP cores are existing
/// know-how with known footprints; we derive one from the kernel text
/// length as a deterministic stand-in).
pub fn estimate_ip_core_resources(opencl_code: &str) -> ResourceEstimate {
    let weight = (opencl_code.len() as u64).max(100);
    ResourceEstimate {
        alms: 20_000 + weight * 40,
        dsps: 64 + weight / 8,
        m20ks: 100 + weight / 16,
    }
}

/// Virtual clock accounting simulated toolchain time.
#[derive(Debug, Default)]
pub struct VirtualClock {
    seconds: Cell<f64>,
}

impl VirtualClock {
    /// Advance the clock by `secs` simulated seconds.
    pub fn advance(&self, secs: f64) {
        self.seconds.set(self.seconds.get() + secs);
    }

    /// Total simulated seconds elapsed.
    pub fn elapsed_secs(&self) -> f64 {
        self.seconds.get()
    }

    /// Total simulated hours elapsed.
    pub fn elapsed_hours(&self) -> f64 {
        self.seconds.get() / 3600.0
    }
}

/// One kernel submitted to the HLS chain.
#[derive(Debug, Clone)]
pub struct KernelSpec {
    /// Kernel name (diagnostics).
    pub name: String,
    /// Static resource estimate checked by the pre-check.
    pub resources: ResourceEstimate,
    /// Iterations of the pipelined loop per invocation.
    pub trips: u64,
    /// Initiation interval achieved by the pipeline (1 = fully pipelined).
    pub ii: u64,
    /// Bytes moved host<->device per invocation.
    pub transfer_bytes: u64,
}

/// A successfully compiled kernel with its timing model.
#[derive(Debug, Clone)]
pub struct CompiledKernel {
    /// The submitted kernel.
    pub spec: KernelSpec,
    /// Device the kernel was compiled for.
    pub device: Device,
    /// Simulated seconds the compile consumed.
    pub compile_secs: f64,
}

/// Pipeline fill latency charged to every kernel invocation (cycles).
pub const PIPELINE_FILL_CYCLES: f64 = 100.0;

/// Effective host<->device PCIe bandwidth of the modeled card (bytes/s).
pub const PCIE_BYTES_PER_SEC: f64 = 6.0e9;

/// Modeled execution time of one kernel invocation on `device`: pipeline
/// fill + trips×II cycles at `fmax`, plus PCIe transfer at
/// [`PCIE_BYTES_PER_SEC`]. This is the estimate the backend-arbitration
/// stage compares against the *measured* GPU time before committing to an
/// hours-long compile; [`CompiledKernel::exec_secs`] reports the same
/// number after the compile, so the pre-compile estimate is exact by
/// construction (DESIGN.md "Substitutions").
pub fn modeled_exec_secs(spec: &KernelSpec, device: &Device) -> f64 {
    let cycles = PIPELINE_FILL_CYCLES + (spec.trips * spec.ii) as f64;
    cycles / device.fmax + spec.transfer_bytes as f64 / PCIE_BYTES_PER_SEC
}

impl CompiledKernel {
    /// Modeled execution time per invocation (see [`modeled_exec_secs`]).
    pub fn exec_secs(&self) -> f64 {
        modeled_exec_secs(&self.spec, &self.device)
    }
}

/// Simulated Intel HLS chain (Quartus synthesis + place&route).
pub struct HlsCompiler {
    /// Target device.
    pub device: Device,
    /// Accounts simulated toolchain time across pre-checks and compiles.
    pub clock: VirtualClock,
    /// Base compile latency in simulated seconds (paper: ≈3 h).
    pub base_compile_secs: f64,
    /// Fraction of the compile after which resource overflow errors out
    /// (paper: "errors early when the resource amount is over").
    pub early_error_fraction: f64,
}

impl HlsCompiler {
    /// New compiler chain for a device with paper-calibrated timings.
    pub fn new(device: Device) -> Self {
        HlsCompiler {
            device,
            clock: VirtualClock::default(),
            base_compile_secs: 3.0 * 3600.0,
            early_error_fraction: 0.1,
        }
    }

    /// Fast pre-check (OpenCL pre-compile / report stage): no P&R, only a
    /// resource report. Costs minutes, not hours.
    pub fn precheck(&self, spec: &KernelSpec) -> Result<()> {
        self.clock.advance(120.0);
        if !spec.resources.fits(&self.device) {
            bail!(
                "{}: resource estimate exceeds {} (ALM {}/{}, DSP {}/{}, M20K {}/{})",
                spec.name,
                self.device.name,
                spec.resources.alms,
                self.device.alms,
                spec.resources.dsps,
                self.device.dsps,
                spec.resources.m20ks,
                self.device.m20ks,
            );
        }
        Ok(())
    }

    /// Full compile: consumes simulated hours; resource overflow errors at
    /// `early_error_fraction` of the way in.
    pub fn compile(&self, spec: &KernelSpec) -> Result<CompiledKernel> {
        // Compile time grows mildly with utilization (placement pressure).
        let util = spec.resources.utilization(&self.device).min(2.0);
        let full = self.base_compile_secs * (1.0 + util);
        if !spec.resources.fits(&self.device) {
            self.clock.advance(full * self.early_error_fraction);
            bail!("{}: HLS aborted — resource overflow on {}", spec.name, self.device.name);
        }
        self.clock.advance(full);
        Ok(CompiledKernel { spec: spec.clone(), device: self.device, compile_secs: full })
    }
}

/// The paper's FPGA candidate-narrowing flow: rank by arithmetic
/// intensity, pre-check resources, full-compile only the top `k`
/// survivors, and return them with timing models (fastest first).
pub fn narrow_and_compile(
    compiler: &HlsCompiler,
    candidates: &[KernelSpec],
    intensity: &[f64],
    k: usize,
) -> Vec<CompiledKernel> {
    let mut order: Vec<usize> = (0..candidates.len()).collect();
    order.sort_by(|&a, &b| intensity[b].partial_cmp(&intensity[a]).unwrap());

    let mut compiled = Vec::new();
    for &i in &order {
        if compiled.len() >= k {
            break;
        }
        let spec = &candidates[i];
        if compiler.precheck(spec).is_err() {
            continue;
        }
        if let Ok(c) = compiler.compile(spec) {
            compiled.push(c);
        }
    }
    compiled.sort_by(|a, b| a.exec_secs().partial_cmp(&b.exec_secs()).unwrap());
    compiled
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, dsps: u64, trips: u64) -> KernelSpec {
        KernelSpec {
            name: name.into(),
            resources: ResourceEstimate { alms: 50_000, dsps, m20ks: 200 },
            trips,
            ii: 1,
            transfer_bytes: 1 << 20,
        }
    }

    #[test]
    fn fits_and_utilization() {
        let r = ResourceEstimate { alms: 100_000, dsps: 759, m20ks: 100 };
        assert!(r.fits(&ARRIA10_GX));
        assert!((r.utilization(&ARRIA10_GX) - 0.5).abs() < 1e-3);
        let too_big = ResourceEstimate { dsps: 10_000, ..r };
        assert!(!too_big.fits(&ARRIA10_GX));
    }

    #[test]
    fn compile_consumes_simulated_hours() {
        let hls = HlsCompiler::new(ARRIA10_GX);
        hls.compile(&spec("k1", 400, 1 << 20)).unwrap();
        assert!(hls.clock.elapsed_hours() >= 3.0);
    }

    #[test]
    fn overflow_errors_early_and_cheap() {
        let hls = HlsCompiler::new(ARRIA10_GX);
        let bad = spec("huge", 50_000, 1024);
        let err = hls.compile(&bad).unwrap_err();
        assert!(err.to_string().contains("resource overflow"));
        // Early error: way below a full compile.
        assert!(hls.clock.elapsed_hours() < 1.5);
    }

    #[test]
    fn precheck_is_cheap() {
        let hls = HlsCompiler::new(ARRIA10_GX);
        assert!(hls.precheck(&spec("ok", 100, 10)).is_ok());
        assert!(hls.precheck(&spec("big", 99_999, 10)).is_err());
        assert!(hls.clock.elapsed_secs() < 600.0);
    }

    #[test]
    fn timing_model_scales_with_trips_and_transfer() {
        let hls = HlsCompiler::new(ARRIA10_GX);
        let small = hls.compile(&spec("s", 100, 1_000)).unwrap();
        let big = hls.compile(&spec("b", 100, 10_000_000)).unwrap();
        assert!(big.exec_secs() > small.exec_secs() * 10.0);
    }

    #[test]
    fn narrowing_compiles_only_top_k() {
        let hls = HlsCompiler::new(ARRIA10_GX);
        let cands = vec![
            spec("low", 100, 1_000),
            spec("high", 100, 1 << 22),
            spec("mid", 100, 1 << 16),
            spec("overflow", 60_000, 1 << 22),
        ];
        let intensity = vec![1.0, 100.0, 10.0, 1000.0];
        let out = narrow_and_compile(&hls, &cands, &intensity, 2);
        // "overflow" is highest intensity but fails precheck; the two
        // compiled are high + mid.
        assert_eq!(out.len(), 2);
        let names: Vec<&str> = out.iter().map(|c| c.spec.name.as_str()).collect();
        assert!(names.contains(&"high") && names.contains(&"mid"));
        // Two full compiles + prechecks only — not four compiles.
        assert!(hls.clock.elapsed_hours() < 16.0);
    }

    #[test]
    fn exact_fit_passes_precheck_and_compiles() {
        // A kernel consuming the device to the last ALM/DSP/M20K is still
        // placeable: the pre-check is `<=`, not `<`.
        let hls = HlsCompiler::new(ARRIA10_GX);
        let exact = KernelSpec {
            name: "exact".into(),
            resources: ResourceEstimate {
                alms: ARRIA10_GX.alms,
                dsps: ARRIA10_GX.dsps,
                m20ks: ARRIA10_GX.m20ks,
            },
            trips: 1024,
            ii: 1,
            transfer_bytes: 1 << 16,
        };
        assert!(hls.precheck(&exact).is_ok());
        let k = hls.compile(&exact).unwrap();
        assert!((k.spec.resources.utilization(&ARRIA10_GX) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn each_resource_dimension_overflows_independently() {
        // One resource over budget is enough to reject, whichever it is —
        // and the rejection is the *early* error with its cheap accounting.
        let fit = ResourceEstimate { alms: 100_000, dsps: 500, m20ks: 500 };
        let overflows = [
            ResourceEstimate { alms: ARRIA10_GX.alms + 1, ..fit },
            ResourceEstimate { dsps: ARRIA10_GX.dsps + 1, ..fit },
            ResourceEstimate { m20ks: ARRIA10_GX.m20ks + 1, ..fit },
        ];
        for (i, resources) in overflows.into_iter().enumerate() {
            assert!(!resources.fits(&ARRIA10_GX), "overflow {i} must not fit");
            let hls = HlsCompiler::new(ARRIA10_GX);
            let bad = KernelSpec {
                name: format!("over{i}"),
                resources,
                trips: 1024,
                ii: 1,
                transfer_bytes: 1 << 16,
            };
            // Pre-check: rejected for ~minutes of simulated time.
            assert!(hls.precheck(&bad).is_err());
            assert!(hls.clock.elapsed_secs() < 600.0, "pre-check must stay cheap");
            // Full compile without a pre-check: errors early, far below the
            // ≥3 h a successful compile would charge.
            let before = hls.clock.elapsed_hours();
            assert!(hls.compile(&bad).is_err());
            let charged = hls.clock.elapsed_hours() - before;
            assert!(
                charged > 0.0 && charged < 1.0,
                "early error must charge (0, 1) h, charged {charged}"
            );
        }
    }

    #[test]
    fn modeled_estimate_matches_compiled_timing() {
        // The arbitration stage estimates before compiling; the estimate
        // must equal what the compiled kernel reports.
        let hls = HlsCompiler::new(ARRIA10_GX);
        let s = spec("k", 200, 1 << 18);
        let est = modeled_exec_secs(&s, &ARRIA10_GX);
        let compiled = hls.compile(&s).unwrap();
        assert_eq!(est, compiled.exec_secs());
        assert!(est > 0.0);
    }

    #[test]
    fn loop_resource_estimation_monotone_in_unroll() {
        let r = IntensityReport {
            flops_per_iter: 4,
            mem_per_iter: 2,
            trips: Some(1024),
            ratio: 2.0,
            score: 2048.0,
        };
        let u1 = estimate_loop_resources(&r, 1);
        let u8 = estimate_loop_resources(&r, 8);
        assert!(u8.dsps > u1.dsps && u8.alms > u1.alms);
    }

    #[test]
    fn ip_core_estimate_fits_device() {
        let est = estimate_ip_core_resources("__kernel void k() {}");
        assert!(est.fits(&ARRIA10_GX));
    }
}
