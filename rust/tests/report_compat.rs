//! Report-JSON version compatibility: a committed v1 fixture (the
//! pre-arbitration format) must still decode, a v2 fixture must
//! round-trip byte-identically through `coordinator/report_json.rs` —
//! the invariant the decision cache's byte-identical replay rests on —
//! and synthesized v3 (power residue), v4 (estimate residue), and v5
//! (residency residue) documents must decode and stay codec fixed
//! points.

use fbo::coordinator::{report_json, Backend, BackendPolicy};
use fbo::patterndb::json::{self, Json};
use fbo::transform::Reconciliation;

const V1_FIXTURE: &str = include_str!("fixtures/report_v1.json");
const V2_FIXTURE: &str = include_str!("fixtures/report_v2.json");

#[test]
fn committed_v1_fixture_still_decodes() {
    let report = report_json::report_from_str(V1_FIXTURE)
        .expect("v1 reports must stay decodable");
    assert_eq!(report.entry, "main");
    assert_eq!(report.external_callees, vec!["ludcmp".to_string()]);
    assert_eq!(report.blocks.len(), 1);
    assert_eq!(
        report.blocks[0].plan.reconciliation,
        Reconciliation::DropOptional(vec![2])
    );
    assert_eq!(report.outcome.best_speedup, 8.0);
    // v1 predates per-pattern traffic: it reads as zero.
    assert_eq!(report.outcome.tried[0].traffic.dispatches, 0);
    // v1 predates arbitration: the section is synthesized for the GPU-only
    // configuration the v1 pipeline effectively ran under.
    assert_eq!(report.arbitration.policy, BackendPolicy::Gpu);
    assert_eq!(report.backend(), Backend::Gpu);
    assert!(report.arbitration.blocks.is_empty());
    assert_eq!(report.arbitration.simulated_hours, 0.0);
    assert!(report.arbitration.gpu_request_secs.is_some());
    assert!(report.arbitration.fpga_request_secs.is_none());
}

#[test]
fn v1_fixture_upgrades_to_v2_on_reencode() {
    let report = report_json::report_from_str(V1_FIXTURE).unwrap();
    let upgraded = report_json::report_to_string(&report);
    assert!(upgraded.contains(report_json::REPORT_FORMAT));
    assert!(!upgraded.contains(report_json::REPORT_FORMAT_V1));
    assert!(upgraded.contains("\"arbitration\""));
    // Once upgraded, the canonical form is a fixed point of the codec.
    let again = report_json::report_to_string(&report_json::report_from_str(&upgraded).unwrap());
    assert_eq!(again, upgraded);
}

#[test]
fn committed_v2_fixture_round_trips_byte_identically() {
    let report = report_json::report_from_str(V2_FIXTURE).expect("v2 fixture must decode");
    assert_eq!(report.entry, "main");
    assert_eq!(report.backend(), Backend::Fpga);
    assert_eq!(report.outcome.tried[0].traffic.bytes_in, 32768);
    let reencoded = report_json::report_to_string(&report);
    // The canonical print is a fixed point of the codec...
    let twice = report_json::report_to_string(&report_json::report_from_str(&reencoded).unwrap());
    assert_eq!(twice, reencoded, "canonical print must be a codec fixed point");
    // ...and the committed fixture is already in canonical form (modulo
    // the file's trailing newline), so one round trip is byte-identical.
    assert_eq!(reencoded, V2_FIXTURE.trim_end(), "v2 fixture must round-trip byte-identically");
}

#[test]
fn v3_documents_decode_and_are_a_codec_fixed_point() {
    // Shape a v3 document from the committed v2 fixture: bump the format
    // tag and graft a power residue into the arbitration section — the
    // two changes a non-default --power-policy makes to the wire format.
    let mut top = json::parse(V2_FIXTURE).unwrap().as_obj().unwrap().clone();
    top.insert("format".to_string(), Json::str("fbo-offload-report-v3"));
    let power = Json::obj(vec![
        ("policy", Json::str("perf-per-watt")),
        ("gpu_watts", Json::num(75.0)),
        ("fpga_watts", Json::num(40.0)),
        (
            "blocks",
            Json::Arr(vec![Json::obj(vec![
                ("label", Json::str("call:fft2d")),
                ("gpu_energy_j", Json::num(0.0075)),
                ("fpga_energy_j", Json::num(0.0025)),
            ])]),
        ),
    ]);
    if let Some(Json::Obj(arb)) = top.get_mut("arbitration") {
        arb.insert("power".to_string(), power);
    } else {
        panic!("v2 fixture must carry an arbitration section");
    }
    let v3_text = json::to_string_pretty(&Json::Obj(top));

    let report = report_json::report_from_str(&v3_text).expect("v3 documents must decode");
    let residue = report.arbitration.power.as_ref().expect("power residue");
    assert_eq!(residue.gpu_watts, 75.0);
    assert_eq!(residue.blocks[0].fpga_energy_j, Some(0.0025));
    // The canonical re-encode keeps the v3 tag and is a codec fixed point.
    let reencoded = report_json::report_to_string(&report);
    assert!(reencoded.contains(report_json::REPORT_FORMAT_V3));
    assert_eq!(reencoded, v3_text, "canonically-built v3 must round-trip byte-identically");
    let twice = report_json::report_to_string(&report_json::report_from_str(&reencoded).unwrap());
    assert_eq!(twice, reencoded);
}

#[test]
fn v4_documents_decode_and_are_a_codec_fixed_point() {
    // Shape a v4 document from the committed v2 fixture: bump the format
    // tag and graft an estimate residue into the arbitration section —
    // the two changes a non-default estimator config makes to the wire
    // format. v1-v3 documents never carry the section, so the older
    // fixtures above double as the "absent estimate" decode cases.
    let mut top = json::parse(V2_FIXTURE).unwrap().as_obj().unwrap().clone();
    top.insert("format".to_string(), Json::str("fbo-offload-report-v4"));
    let estimate = Json::obj(vec![
        ("policy", Json::str("conservative:0.25")),
        ("gpu_profile", Json::str("GeForce GTX 1050 Ti")),
        ("fpga_profile", Json::str("Arria 10")),
        ("mape", Json::num(0.18)),
        (
            "blocks",
            Json::Arr(vec![Json::obj(vec![
                ("label", Json::str("call:fft2d")),
                ("backend", Json::str("fpga")),
                ("predicted_secs", Json::num(0.0025)),
                ("measured_secs", Json::num(0.003)),
                ("error", Json::num(0.1666666667)),
            ])]),
        ),
    ]);
    if let Some(Json::Obj(arb)) = top.get_mut("arbitration") {
        arb.insert("estimate".to_string(), estimate);
    } else {
        panic!("v2 fixture must carry an arbitration section");
    }
    let v4_text = json::to_string_pretty(&Json::Obj(top));

    let report = report_json::report_from_str(&v4_text).expect("v4 documents must decode");
    let residue = report.arbitration.estimate.as_ref().expect("estimate residue");
    assert_eq!(residue.gpu_profile, "GeForce GTX 1050 Ti");
    assert_eq!(residue.mape, Some(0.18));
    assert_eq!(residue.blocks[0].predicted_secs, 0.0025);
    assert_eq!(residue.blocks[0].measured_secs, Some(0.003));
    // The canonical re-encode keeps the v4 tag and is a codec fixed point.
    let reencoded = report_json::report_to_string(&report);
    assert!(reencoded.contains(report_json::REPORT_FORMAT_V4));
    assert_eq!(reencoded, v4_text, "canonically-built v4 must round-trip byte-identically");
    let twice = report_json::report_to_string(&report_json::report_from_str(&reencoded).unwrap());
    assert_eq!(twice, reencoded);
}

#[test]
fn v5_documents_decode_and_are_a_codec_fixed_point() {
    // Shape a v5 document from the committed v2 fixture: bump the format
    // tag, graft a residency residue into the arbitration section, and
    // give the first pattern's traffic its elided split — the three
    // changes a nonzero --resident-bytes budget makes to the wire format.
    // v1-v4 documents never carry any of them, so the older fixtures
    // above double as the "absent residency" decode cases.
    let mut top = json::parse(V2_FIXTURE).unwrap().as_obj().unwrap().clone();
    top.insert("format".to_string(), Json::str("fbo-offload-report-v5"));
    let residency = Json::obj(vec![
        ("budget_bytes", Json::num(67108864.0)),
        (
            "blocks",
            Json::Arr(vec![Json::obj(vec![
                ("label", Json::str("only:call:fft2d")),
                ("elided_in", Json::num(16384.0)),
                ("elided_out", Json::num(32768.0)),
                ("saved_transfer_secs", Json::num(8.192e-6)),
            ])]),
        ),
        ("total_saved_transfer_secs", Json::num(8.192e-6)),
    ]);
    if let Some(Json::Obj(arb)) = top.get_mut("arbitration") {
        arb.insert("residency".to_string(), residency);
    } else {
        panic!("v2 fixture must carry an arbitration section");
    }
    {
        let Some(Json::Obj(outcome)) = top.get_mut("outcome") else {
            panic!("v2 fixture must carry an outcome section");
        };
        let Some(Json::Arr(tried)) = outcome.get_mut("tried") else {
            panic!("v2 fixture must carry tried patterns");
        };
        let Some(Json::Obj(pattern)) = tried.first_mut() else {
            panic!("v2 fixture must carry at least one pattern");
        };
        let Some(Json::Obj(traffic)) = pattern.get_mut("traffic") else {
            panic!("v2 fixture patterns must carry traffic");
        };
        traffic.insert("elided_in".to_string(), Json::num(16384.0));
        traffic.insert("elided_out".to_string(), Json::num(32768.0));
    }
    let v5_text = json::to_string_pretty(&Json::Obj(top));

    let report = report_json::report_from_str(&v5_text).expect("v5 documents must decode");
    let residue = report.arbitration.residency.as_ref().expect("residency residue");
    assert_eq!(residue.budget_bytes, 64 << 20);
    assert_eq!(residue.blocks[0].elided_in, 16384);
    assert_eq!(residue.blocks[0].elided_out, 32768);
    assert_eq!(residue.total_saved_transfer_secs, 8.192e-6);
    assert_eq!(report.outcome.tried[0].traffic.elided_in, 16384);
    assert_eq!(report.outcome.tried[0].traffic.elided_out, 32768);
    // The canonical re-encode keeps the v5 tag and is a codec fixed point.
    let reencoded = report_json::report_to_string(&report);
    assert!(reencoded.contains(report_json::REPORT_FORMAT_V5));
    assert_eq!(reencoded, v5_text, "canonically-built v5 must round-trip byte-identically");
    let twice = report_json::report_to_string(&report_json::report_from_str(&reencoded).unwrap());
    assert_eq!(twice, reencoded);

    // Tag <-> payload agreement: a v5 tag without the residency section
    // (and the reverse) must be rejected as corrupt.
    let v4_tagged = v5_text.replace("fbo-offload-report-v5", "fbo-offload-report-v4");
    assert!(report_json::report_from_str(&v4_tagged).is_err(), "v4 tag + residency must fail");
    assert!(
        report_json::report_from_str(&V2_FIXTURE.replace(
            report_json::REPORT_FORMAT,
            "fbo-offload-report-v5"
        ))
        .is_err(),
        "v5 tag without residency must fail"
    );
}
