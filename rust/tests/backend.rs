//! Integration tests for the backend-arbitration stage: `--target`
//! semantics end-to-end, the fail-fast resource pre-check, report-codec
//! round-trips of real arbitrations, and decision-cache invalidation on
//! device-model changes.

use std::path::PathBuf;

use fbo::coordinator::{apps, report_json, Backend, BackendPolicy, Coordinator};
use fbo::fpga;
use fbo::service::{OffloadService, ServiceConfig};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn coordinator() -> Coordinator {
    let mut c = Coordinator::open(&artifacts_dir()).expect("run `make artifacts` first");
    c.verify.reps = 1;
    c
}

// ------------------------------------------------------------ --target auto

#[test]
fn auto_arbitrates_fpga_and_gpu_across_eval_apps() {
    let c = coordinator();

    // matmul has no DB-registered IP core: auto must keep it on the GPU.
    let mm = c.offload(&apps::matmul_app(64), "main").unwrap();
    assert_eq!(mm.backend(), Backend::Gpu, "no IP core -> gpu");
    let mm_block = &mm.arbitration.blocks[0];
    assert!(mm_block.fpga.is_none());

    // FFT and LU both have IP cores; at n=64 the streaming estimate beats
    // the measured PJRT device seconds for at least one of them (the
    // acceptance shape: fpga for one eval app, gpu for another). This
    // compares a modeled constant (~60-75 µs at n=64) against measured
    // wall-clock, so it is hardware-dependent in principle — in practice
    // one PJRT dispatch here pays literal creation + execute + readback
    // over 16-32 KB buffers, well above the modeled bar on any current
    // CPU; `cargo bench --bench backend_arbitration` cross-checks the
    // same property outside tier-1.
    let fft = c.offload(&apps::fft_app_lib(64), "main").unwrap();
    let lu = c.offload(&apps::lu_app_lib(64), "main").unwrap();
    let fpga_apps = [&fft, &lu]
        .iter()
        .filter(|r| r.backend() == Backend::Fpga)
        .count();
    assert!(
        fpga_apps >= 1,
        "expected an FPGA winner; fft {:?} lu {:?}",
        fft.arbitration,
        lu.arbitration
    );

    // Whoever chose FPGA did it for the modeled reason (estimate below the
    // measurement) and paid the simulated compile.
    for r in [&fft, &lu] {
        if r.backend() != Backend::Fpga {
            continue;
        }
        let block = r
            .arbitration
            .blocks
            .iter()
            .find(|b| b.backend == Backend::Fpga)
            .expect("an FPGA block behind an FPGA report");
        let est = block.fpga.as_ref().unwrap();
        assert!(est.precheck_ok && !est.narrowed_out);
        assert!(est.est_secs < block.gpu_device_secs);
        assert!(r.arbitration.simulated_hours >= 3.0, "compile hours charged");
        // Step 5 gets both request times out of this decision.
        assert!(r.arbitration.gpu_request_secs.is_some());
        assert!(r.arbitration.fpga_request_secs.is_some());
    }
}

#[test]
fn real_arbitration_round_trips_through_the_codec() {
    let c = coordinator();
    let report = c.offload(&apps::fft_app_lib(64), "main").unwrap();
    let s = report_json::report_to_string(&report);
    let back = report_json::report_from_str(&s).unwrap();
    assert_eq!(back.arbitration, report.arbitration);
    assert_eq!(report_json::report_to_string(&back), s, "byte-stable");
    assert!(s.contains("\"backend\""), "top-level backend field present");
}

// ------------------------------------------------------------ --target gpu

#[test]
fn gpu_target_reproduces_the_papers_configuration() {
    let mut c = coordinator();
    c.backend_policy = BackendPolicy::Gpu;
    let r = c.offload(&apps::fft_app_lib(64), "main").unwrap();
    assert_eq!(r.backend(), Backend::Gpu);
    assert!(r.arbitration.blocks.iter().all(|b| b.fpga.is_none()));
    assert_eq!(r.arbitration.simulated_hours, 0.0, "no toolchain under --target gpu");
    assert!(r.best_speedup() > 3.0, "arbitration must not disturb Step 3");
}

// ----------------------------------------------------------- --target fpga

#[test]
fn fpga_target_forces_the_core_and_charges_the_compile() {
    let mut c = coordinator();
    c.backend_policy = BackendPolicy::Fpga;
    let r = c.offload(&apps::lu_app_lib(64), "main").unwrap();
    assert_eq!(r.backend(), Backend::Fpga);
    assert!(r.arbitration.simulated_hours >= 3.0);
    // The transformed source is backend-neutral (same artifact glue).
    assert!(r.transformed_source.contains("__fb_lu_factor"));
}

#[test]
fn fpga_target_fails_fast_on_over_resource_kernel() {
    let mut c = coordinator();
    c.backend_policy = BackendPolicy::Fpga;
    // Register an IP core whose OpenCL footprint overflows the Arria10:
    // the static estimate scales with the kernel text.
    let idx = c
        .db
        .fpga_ip_cores
        .iter()
        .position(|core| core.artifact == "lu_factor")
        .unwrap();
    c.db.fpga_ip_cores[idx].opencl_code = Some("x".repeat(20_000));

    let err = c.offload(&apps::lu_app_lib(64), "main").unwrap_err().to_string();
    assert!(err.contains("pre-check"), "{err}");
    // Fail-fast contract: simulated hours are reported and sit far below
    // a single ~3 h compile (the pre-check costs minutes).
    let hours: f64 = err
        .split("rejected by the resource pre-check after ")
        .nth(1)
        .and_then(|s| s.split(' ').next())
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("hours missing from: {err}"));
    assert!(hours < 1.0, "{err}");
}

// ------------------------------------------------- decision-cache keying

#[test]
fn device_model_change_invalidates_cached_decisions() {
    let dir = std::env::temp_dir()
        .join(format!("fbo-backendtest-device-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = ServiceConfig::new(artifacts_dir());
    cfg.cache_dir = Some(dir.clone());
    cfg.workers = 1;
    cfg.verify.reps = 1;
    let src = apps::lu_app_lib(64);

    let first_json = {
        let service = OffloadService::start(cfg.clone()).unwrap();
        let first = service.submit(&src, "main").wait().unwrap();
        assert!(!first.from_cache);
        first.report_json
    };

    // Same device model after restart: byte-identical replay.
    {
        let service = OffloadService::start(cfg.clone()).unwrap();
        let replay = service.submit(&src, "main").wait().unwrap();
        assert!(replay.from_cache, "same device must replay");
        assert_eq!(replay.report_json, first_json);
    }

    // Retargeted device model (higher fmax): every cached decision must
    // miss and re-verify.
    {
        let mut retargeted = cfg.clone();
        retargeted.device = fpga::Device { fmax: 300.0e6, ..fpga::ARRIA10_GX };
        let service = OffloadService::start(retargeted).unwrap();
        let fresh = service.submit(&src, "main").wait().unwrap();
        assert!(!fresh.from_cache, "device change must miss the cache");
    }

    // And a different --target misses too.
    {
        let mut gpu_only = cfg;
        gpu_only.backend_policy = BackendPolicy::Gpu;
        let service = OffloadService::start(gpu_only).unwrap();
        let fresh = service.submit(&src, "main").wait().unwrap();
        assert!(!fresh.from_cache, "--target change must miss the cache");
        assert_eq!(fresh.report.backend(), Backend::Gpu);
    }

    std::fs::remove_dir_all(&dir).ok();
}
