//! Integration tests across the whole stack (runtime + coordinator +
//! transform + interpreter), including failure injection.

use std::path::PathBuf;

use fbo::coordinator::{apps, flow, loop_offload, Coordinator, DiscoveryPath};
use fbo::ga::GaConfig;
use fbo::parser;
use fbo::runtime::Engine;
use fbo::transform::InterfacePolicy;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn coordinator() -> Coordinator {
    let mut c = Coordinator::open(&artifacts_dir()).expect("run `make artifacts` first");
    c.verify.reps = 1;
    c
}

// ---------------------------------------------------------------- discovery

#[test]
fn both_discovery_paths_land_on_the_same_artifact() {
    // Paper §5.1: the same app is prepared as library-call and copied-code
    // variants; both must be discovered and replaced.
    let c = coordinator();
    let lib = c.offload(&apps::lu_app_lib(64), "main").unwrap();
    let copy = c.offload(&apps::lu_app_copy(64), "main").unwrap();

    assert!(lib
        .blocks
        .iter()
        .any(|b| matches!(&b.via, DiscoveryPath::LibraryMatch { .. })));
    assert!(copy
        .blocks
        .iter()
        .any(|b| matches!(&b.via, DiscoveryPath::Similarity { .. })));
    // Same artifact behind both.
    assert!(lib.transformed_source.contains("__fb_lu_factor"));
    assert!(copy.transformed_source.contains("__fb_lu_factor"));
    // Both accelerate.
    assert!(lib.best_speedup() > 5.0, "{}", lib.best_speedup());
    assert!(copy.best_speedup() > 5.0, "{}", copy.best_speedup());
}

#[test]
fn unknown_library_is_not_offloaded() {
    let c = coordinator();
    let src = "
        void mystery_op(double a[], int n);
        int main() {
            double a[16];
            for (int i = 0; i < 16; i++) a[i] = i;
            mystery_op(a, 16);
            return a[0];
        }";
    let prog = parser::parse(src).unwrap();
    let (_, blocks) = c.discover(&prog).unwrap();
    assert!(blocks.is_empty(), "{blocks:?}");
}

#[test]
fn fb_beats_loop_offload_on_both_apps() {
    // The paper's core claim, at test scale.
    let c = coordinator();
    for src in [apps::fft_app_lib(64), apps::lu_app_lib(64)] {
        let fb = c.offload(&src, "main").unwrap();
        let prog = parser::parse(&src).unwrap();
        let linked = c.link_cpu_libraries(&prog).unwrap();
        let cfg = GaConfig { population: 6, generations: 4, ..Default::default() };
        let ga = loop_offload::ga_loop_search(&linked, "main", &cfg, 1, u64::MAX).unwrap();
        assert!(
            fb.best_speedup() > ga.ga.best_speedup(),
            "function blocks ({:.1}x) must beat loop offload ({:.1}x)",
            fb.best_speedup(),
            ga.ga.best_speedup()
        );
    }
}

// ---------------------------------------------------------------- flow 1-7

#[test]
fn full_environment_adaptation_flow() {
    let c = coordinator();
    let report = c.offload(&apps::fft_app_lib(64), "main").unwrap();

    let req = flow::Requirements {
        target_rps: 30.0,
        max_latency_ms: 20.0,
        budget_per_month: 10_000.0,
        max_kwh_per_month: None,
    };
    let plan = flow::plan_resources(report.outcome.best_time.secs(), &req).unwrap();
    assert!(plan.instances >= 1);

    let locations = vec![flow::Location {
        name: "dc".into(),
        gpus: 16,
        fpgas: 8,
        cost_per_hour: 0.5,
        fpga_cost_per_hour: 0.2,
        energy_cost_per_kwh: 0.12,
        latency_ms: 10.0,
    }];
    let placement = flow::plan_placement(&plan, &req, &locations).unwrap();
    assert_eq!(placement.location, "dc");

    // Step 5 with backend arbitration: the report's per-backend times are
    // consumable directly, and at minimum the GPU path is deployable.
    let times = flow::BackendTimes::from_report(&report);
    assert!(times.gpu_secs.is_some(), "winning pattern must offload something");
    let backend_placement = flow::plan_backend_placement(&times, &req, &locations).unwrap();
    assert_eq!(backend_placement.location, "dc");
}

// ---------------------------------------------------------------- policies

#[test]
fn scripted_confirmations_control_c2() {
    // An app whose copied LU has an extra debug parameter: C-2 must ask.
    let src = format!(
        "{}
        int main() {{
            double a[32 * 32];
            int i;
            for (i = 0; i < 32 * 32; i++) a[i] = 0.1;
            for (i = 0; i < 32; i++) a[i * 32 + i] = 32.0;
            factorize(a, 32, 1);
            double s = 0.0;
            for (i = 0; i < 32; i++) s += a[i * 32 + i];
            return s;
        }}",
        fbo::patterndb::corpus::NR_LUDCMP
            .replace("ludcmp_nopiv(double a[], int n)", "factorize(double a[], int n, int dbg)")
            .replace("ludcmp_nopiv", "factorize")
    );
    let mut c = coordinator();
    c.policy = InterfacePolicy::AutoReject;
    let prog = parser::parse(&src).unwrap();
    let (_, blocks) = c.discover(&prog).unwrap();
    let sim_block = blocks
        .iter()
        .find(|b| matches!(&b.via, DiscoveryPath::Similarity { .. }));
    if let Some(b) = sim_block {
        assert!(
            !b.accepted(),
            "strict policy must reject the extra-arg interface change: {:?}",
            b.plan.reconciliation
        );
    }
    // Approving policy accepts (drops the extra arg).
    c.policy = InterfacePolicy::AutoApprove;
    let (_, blocks) = c.discover(&prog).unwrap();
    let accepted_sim = blocks
        .iter()
        .any(|b| matches!(&b.via, DiscoveryPath::Similarity { .. }) && b.accepted());
    assert!(accepted_sim, "approving policy must accept: {blocks:?}");
}

// ---------------------------------------------------------------- failures

#[test]
fn missing_artifacts_dir_is_a_clean_error() {
    let err = match Engine::open(&PathBuf::from("/nonexistent/fbo-artifacts")) {
        Err(e) => e,
        Ok(_) => panic!("open of nonexistent dir must fail"),
    };
    assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
}

#[test]
fn corrupt_manifest_is_a_clean_error() {
    let dir = std::env::temp_dir().join(format!("fbo-corrupt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    assert!(Engine::open(&dir).is_err());
    std::fs::write(dir.join("manifest.json"), r#"{"format":"other","artifacts":[]}"#).unwrap();
    assert!(Engine::open(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_artifact_file_fails_at_compile_not_open() {
    let dir = std::env::temp_dir().join(format!("fbo-missing-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format":"hlo-text","artifacts":[{"name":"ghost_n8","file":"ghost_n8.hlo.txt",
            "inputs":[{"shape":[8,8],"dtype":"f32"}],"outputs":[{"shape":[8,8],"dtype":"f32"}]}]}"#,
    )
    .unwrap();
    let engine = Engine::open(&dir).unwrap();
    assert!(engine.has_artifact("ghost_n8"));
    assert!(engine.execute("ghost_n8", &[vec![0f32; 64]]).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn diverging_candidate_is_contained_by_fuel() {
    // A pathological app whose baseline would loop forever: the verify
    // config's fuel turns it into a clean error instead of a hang.
    let c = {
        let mut c = coordinator();
        c.verify.fuel = 100_000;
        c
    };
    let src = "
        void ludcmp(double a[], int n);
        int main() {
            double a[4];
            while (1) { a[0] = a[0] + 1.0; }
            ludcmp(a, 2);
            return 0;
        }";
    assert!(c.offload(src, "main").is_err());
}

#[test]
fn entry_function_must_exist() {
    let c = coordinator();
    assert!(c.offload("int main() { return 0; }", "nonexistent").is_err());
}

// ---------------------------------------------------------------- sizes

#[test]
fn size_variants_resolve_per_app_size() {
    // n=64 apps use *_n64 artifacts; a size with no artifact fails the
    // pattern (not the search).
    let c = coordinator();
    let report = c.offload(&apps::lu_app_lib(64), "main").unwrap();
    assert!(report.best_speedup() > 1.0);

    // n=48 has no artifact: the offload pattern fails its trial and the
    // search falls back to all-CPU (best = no blocks enabled).
    let report = c.offload(&apps::lu_app_lib(48), "main").unwrap();
    assert!(report.outcome.best_enabled.iter().all(|&e| !e));
    assert!(report
        .outcome
        .tried
        .iter()
        .all(|p| p.speedup <= 1.0 || !p.output_ok || p.label.contains("failed")));
}

// ---------------------------------------------------------------- stats

#[test]
fn engine_stats_reflect_verification_traffic() {
    let c = coordinator();
    let before = c.engine.stats.borrow().executions;
    let _ = c.offload(&apps::fft_app_lib(64), "main").unwrap();
    let after = c.engine.stats.borrow().executions;
    assert!(after > before, "verification must have executed artifacts");
}
