//! Property-based tests over the L3 substrates.
//!
//! No external property-testing crate is vendored, so these use the GA's
//! deterministic PRNG to generate hundreds of random cases per property —
//! same discipline (generate, check invariant, shrink-by-seed when it
//! fails: the failing seed is printed).

use fbo::ga::rng::Rng;
use fbo::interp::{offload_exec, Interp, Value};
use fbo::parser::{self, print_program};
use fbo::similarity::{similarity, CharVector};

// ------------------------------------------------------------------
// Random program generation (a tiny grammar-directed generator).
// ------------------------------------------------------------------

struct Gen {
    rng: Rng,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed) }
    }

    fn expr(&mut self, vars: &[&str], depth: usize) -> String {
        if depth == 0 || self.rng.bool_with(0.35) {
            match self.rng.below(3) {
                0 => format!("{}", self.rng.below(100)),
                1 => format!("{}.5", self.rng.below(50)),
                _ => vars[self.rng.below(vars.len())].to_string(),
            }
        } else {
            let op = ["+", "-", "*"][self.rng.below(3)];
            format!(
                "({} {} {})",
                self.expr(vars, depth - 1),
                op,
                self.expr(vars, depth - 1)
            )
        }
    }

    fn stmt(&mut self, vars: &[&str], depth: usize) -> String {
        match self.rng.below(if depth == 0 { 2 } else { 4 }) {
            0 => format!("{} = {};", vars[self.rng.below(vars.len())], self.expr(vars, 2)),
            1 => format!("s += {};", self.expr(vars, 2)),
            2 => format!(
                "if ({} > {}) {{ {} }} else {{ {} }}",
                self.expr(vars, 1),
                self.expr(vars, 1),
                self.stmt(vars, depth - 1),
                self.stmt(vars, depth - 1)
            ),
            _ => format!(
                "for (int q{d} = 0; q{d} < {}; q{d}++) {{ {} }}",
                2 + self.rng.below(5),
                self.stmt(vars, depth - 1),
                d = depth
            ),
        }
    }

    fn program(&mut self) -> String {
        let mut body = String::new();
        for _ in 0..(1 + self.rng.below(6)) {
            body.push_str(&self.stmt(&["x", "y", "z"], 2));
            body.push('\n');
        }
        format!(
            "double main() {{\n double x = 1.0; double y = 2.0; double z = 0.0; double s = 0.0;\n{body}\n return s + x + y + z;\n}}"
        )
    }
}

#[test]
fn prop_parse_print_roundtrip() {
    for seed in 0..300u64 {
        let src = Gen::new(seed).program();
        let prog = parser::parse(&src).unwrap_or_else(|e| panic!("seed {seed}: parse {e}\n{src}"));
        let printed = print_program(&prog);
        let reparsed = parser::parse(&printed)
            .unwrap_or_else(|e| panic!("seed {seed}: reparse {e}\n{printed}"));
        assert_eq!(
            printed,
            print_program(&reparsed),
            "seed {seed}: print∘parse not idempotent"
        );
    }
}

#[test]
fn prop_interpreter_deterministic() {
    for seed in 0..100u64 {
        let src = Gen::new(seed).program();
        let prog = parser::parse(&src).unwrap();
        let run = || -> f64 {
            let mut m = Interp::new(&prog).unwrap();
            m.fuel = 3_000_000;
            match m.run("main", &[]) {
                Ok(v) => v.as_num().unwrap_or(f64::NAN),
                Err(_) => f64::NAN, // fuel exhaustion etc. must also be stable
            }
        };
        let a = run();
        let b = run();
        assert!(
            (a.is_nan() && b.is_nan()) || a == b,
            "seed {seed}: non-deterministic ({a} vs {b})"
        );
    }
}

// ------------------------------------------------------------------
// Bulk executor ≡ interpreter on generated offloadable loops.
// ------------------------------------------------------------------

fn elementwise_program(seed: u64) -> String {
    let mut g = Gen::new(seed);
    let n = 16 + g.rng.below(48);
    let coef = 1 + g.rng.below(9);
    let off = g.rng.below(7);
    format!(
        "double main() {{
            double a[{n}]; double b[{n}];
            for (int i = 0; i < {n}; i++) {{ a[i] = i * 0.5; b[i] = {off}.0; }}
            for (int i = 0; i < {n}; i++) {{
                b[i] = a[i] * {coef}.0 + sin(a[i]) - b[i];
            }}
            double s = 0.0;
            for (int i = 0; i < {n}; i++) s += b[i];
            return s;
        }}"
    )
}

#[test]
fn prop_bulk_executor_matches_interpreter() {
    for seed in 0..80u64 {
        let src = elementwise_program(seed);
        let prog = parser::parse(&src).unwrap();

        let mut plain = Interp::new(&prog).unwrap();
        let expected = plain.run("main", &[]).unwrap().as_num().unwrap();

        // Offload every for-loop that compiles.
        let mut ids = std::collections::HashSet::new();
        for f in prog.functions() {
            if let Some(b) = &f.body {
                b.walk(&mut |s| {
                    if matches!(s.kind, fbo::parser::StmtKind::For { .. })
                        && offload_exec::compile_loop(s).is_some()
                    {
                        ids.insert(s.id);
                    }
                });
            }
        }
        assert!(!ids.is_empty(), "seed {seed}: no offloadable loops generated");
        let mut bulk = Interp::new(&prog).unwrap();
        bulk.set_offloaded_loops(ids);
        let got = bulk.run("main", &[]).unwrap().as_num().unwrap();
        assert!(
            (got - expected).abs() <= 1e-9 * expected.abs().max(1.0),
            "seed {seed}: bulk {got} != interp {expected}"
        );
        assert!(bulk.stats.bulk_loops > 0, "seed {seed}: bulk path not taken");
    }
}

// ------------------------------------------------------------------
// Similarity metric properties.
// ------------------------------------------------------------------

fn random_vector(seed: u64) -> CharVector {
    let mut rng = Rng::new(seed);
    let mut v = CharVector::default();
    for c in v.counts.iter_mut() {
        *c = rng.below(20) as u32;
    }
    v
}

#[test]
fn prop_similarity_identity_symmetry_bounds() {
    for seed in 0..200u64 {
        let a = random_vector(seed);
        let b = random_vector(seed.wrapping_add(1_000_003));
        let sab = similarity(&a, &b);
        let sba = similarity(&b, &a);
        assert!((sab - sba).abs() < 1e-12, "seed {seed}: asymmetric");
        assert!((0.0..=1.0).contains(&sab), "seed {seed}: out of range {sab}");
        assert!((similarity(&a, &a) - 1.0).abs() < 1e-12, "seed {seed}: self-sim != 1");
    }
}

#[test]
fn prop_similarity_monotone_under_growing_edits() {
    // Adding progressively more junk statements to a function should not
    // (weakly) increase its similarity to the original.
    let base = "void f(double a[], int n) {
        for (int i = 0; i < n; i++) a[i] = a[i] * 2.0;
    }";
    let v0 = CharVector::from_source_merged(base).unwrap();
    let mut prev = 1.0f64;
    for k in 1..=6 {
        let mut edited = String::from(
            "void f(double a[], int n) {\n  for (int i = 0; i < n; i++) a[i] = a[i] * 2.0;\n",
        );
        for j in 0..k * 3 {
            edited.push_str(&format!("  double t{j} = {j}.0; t{j} = t{j} + 1.0; a[0] += t{j};\n"));
        }
        edited.push('}');
        let v = CharVector::from_source_merged(&edited).unwrap();
        let s = similarity(&v0, &v);
        assert!(s <= prev + 1e-9, "edit size {k}: similarity rose ({s} > {prev})");
        prev = s;
    }
    assert!(prev < 0.9, "large edits must reduce similarity below 0.9, got {prev}");
}

// ------------------------------------------------------------------
// GA invariants on random fitness landscapes.
// ------------------------------------------------------------------

#[test]
fn prop_ga_never_worse_than_baseline_and_monotone() {
    use fbo::ga::{self, GaConfig};
    use std::time::Duration;

    for seed in 0..30u64 {
        let mut rng = Rng::new(seed);
        let n = 3 + rng.below(5);
        // Random per-gene contributions (some positive, some negative).
        let contrib: Vec<f64> =
            (0..n).map(|_| (rng.next_f64() - 0.45) * 400.0).collect();
        let mut fitness = |gene: &[bool]| -> anyhow::Result<Duration> {
            let mut t = 1000.0;
            for (g, c) in gene.iter().zip(&contrib) {
                if *g {
                    t -= c;
                }
            }
            Ok(Duration::from_secs_f64(t.max(1.0) / 1000.0))
        };
        let cfg = GaConfig { population: 8, generations: 6, seed, ..Default::default() };
        let r = ga::run(n, &cfg, &mut fitness).unwrap();
        assert!(r.best_speedup() >= 1.0 - 1e-9, "seed {seed}: worse than baseline");
        for w in r.history.windows(2) {
            assert!(
                w[1].best_speedup >= w[0].best_speedup - 1e-9,
                "seed {seed}: best not monotone"
            );
        }
    }
}

// ------------------------------------------------------------------
// JSON round-trip on random documents.
// ------------------------------------------------------------------

#[test]
fn prop_json_roundtrip() {
    use fbo::patterndb::json::{self, Json};

    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        if depth == 0 {
            return match rng.below(4) {
                0 => Json::Null,
                1 => Json::Bool(rng.bool_with(0.5)),
                2 => Json::Num((rng.below(10_000) as f64) - 5000.0),
                _ => Json::Str(format!("s{}", rng.below(1000))),
            };
        }
        match rng.below(6) {
            0 => Json::Null,
            1 => Json::Bool(true),
            2 => Json::Num(rng.next_f64() * 100.0),
            3 => Json::Str(format!("key \"quoted\" \n {}", rng.below(100))),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    for seed in 0..200u64 {
        let mut rng = Rng::new(seed);
        let doc = random_json(&mut rng, 3);
        let text = json::to_string_pretty(&doc);
        let back = json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        // Numbers survive via f64; compare re-serialized forms.
        assert_eq!(
            json::to_string_pretty(&back),
            text,
            "seed {seed}: round-trip mismatch"
        );
    }
}

// ------------------------------------------------------------------
// Decision-cache eviction invariants (model-based).
// ------------------------------------------------------------------

fn cache_key(tag: usize) -> fbo::service::CacheKey {
    fbo::service::CacheKey {
        source_hash: format!("{tag:016x}"),
        entry: "main".to_string(),
        db_fingerprint: "00000000deadbeef".to_string(),
    }
}

/// Canonical JSON payload of a tunable size — the exact bytes a warm
/// disk read must hand back.
fn cache_payload(tag: usize, pad: usize) -> String {
    use fbo::patterndb::json::{to_string_pretty, Json};
    to_string_pretty(&Json::obj(vec![
        ("tag", Json::num(tag as f64)),
        ("pad", Json::str("x".repeat(pad))),
    ]))
}

/// Model-based check of the eviction engine: random inserts, lookups,
/// and gc passes against a reference model that tracks (tier, payload,
/// recency). After every gc the real evictions must match the model's
/// tier-priority-then-LRU prediction exactly, usage must satisfy the
/// budget, and after the run every survivor must replay byte-identically
/// through a fresh `open` of the same directory.
#[test]
fn prop_cache_gc_matches_tier_then_lru_model() {
    use fbo::service::{CacheBudget, CacheTier, DecisionCache};
    use std::collections::HashMap;

    for seed in 0..40u64 {
        let mut rng = Rng::new(seed);
        let dir =
            std::env::temp_dir().join(format!("fbo-proptest-gc-{seed}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = DecisionCache::open(&dir).unwrap();

        // Model: tag -> (tier, payload, last_used). Stamps mirror the
        // cache's single monotonic clock: inserts and lookup *hits* tick
        // it, misses and gc passes do not.
        let mut model: HashMap<usize, (CacheTier, String, u64)> = HashMap::new();
        let mut clock = 1u64;
        for step in 0..50 {
            match rng.below(8) {
                0..=4 => {
                    let tag = rng.below(10);
                    let tier = CacheTier::ALL[rng.below(CacheTier::ALL.len())];
                    let p = cache_payload(tag, rng.below(200));
                    cache.insert_tier(&cache_key(tag), tier, &p).unwrap();
                    model.insert(tag, (tier, p, clock));
                    clock += 1;
                }
                5 | 6 => {
                    let tag = rng.below(10);
                    let got = cache.lookup(&cache_key(tag));
                    match model.get_mut(&tag) {
                        Some(e) => {
                            assert_eq!(got.as_deref(), Some(e.1.as_str()), "seed {seed}");
                            e.2 = clock;
                            clock += 1;
                        }
                        None => assert!(got.is_none(), "seed {seed} step {step}"),
                    }
                }
                _ => {
                    let budget = CacheBudget {
                        max_bytes: Some(rng.below(2000) as u64),
                        max_entries: Some(1 + rng.below(8)),
                    };
                    let dry = rng.bool_with(0.25);
                    let out = cache.gc(budget, dry).unwrap();

                    // The model's prediction: tier rank ascending, then
                    // least-recently-used, dropped until the budget admits.
                    let mut order: Vec<(usize, u64, usize, u64)> = model
                        .iter()
                        .map(|(tag, (tier, p, used))| (tier.rank(), *used, *tag, p.len() as u64))
                        .collect();
                    order.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
                    let mut bytes: u64 = model.values().map(|(_, p, _)| p.len() as u64).sum();
                    let mut count = model.len();
                    let mut expected = Vec::new();
                    for (_, _, tag, size) in order {
                        if budget.admits(bytes, count) {
                            break;
                        }
                        bytes -= size;
                        count -= 1;
                        expected.push(tag);
                    }

                    let got: Vec<usize> = out
                        .evicted
                        .iter()
                        .map(|e| {
                            usize::from_str_radix(&e.key.source_hash, 16)
                                .expect("test keys encode their tag")
                        })
                        .collect();
                    assert_eq!(got, expected, "seed {seed} step {step}: eviction order");
                    if dry {
                        assert_eq!(out.bytes_after, out.bytes_before, "seed {seed}: dry run");
                        assert_eq!(cache.len(), model.len(), "seed {seed}: dry run evicted");
                    } else {
                        for tag in expected {
                            model.remove(&tag);
                        }
                        let u = cache.usage();
                        assert!(
                            budget.admits(u.bytes, u.entries),
                            "seed {seed} step {step}: usage {u:?} exceeds {budget:?}"
                        );
                        assert_eq!(u.bytes, bytes, "seed {seed} step {step}: byte accounting");
                        assert_eq!(u.entries, model.len(), "seed {seed} step {step}");
                    }
                }
            }
        }

        // Crash-consistency epilogue: a fresh open of the same directory
        // sees exactly the survivors, each byte-identical.
        drop(cache);
        let reopened = DecisionCache::open(&dir).unwrap();
        assert_eq!(reopened.stats().corrupt, 0, "seed {seed}: gc must never corrupt");
        assert_eq!(reopened.len(), model.len(), "seed {seed}: survivors after reopen");
        for (tag, (_, p, _)) in &model {
            assert_eq!(
                reopened.lookup(&cache_key(*tag)).as_deref(),
                Some(p.as_str()),
                "seed {seed}: survivor must replay byte-identically"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// A standing budget is an invariant, not a goal: after *every* insert
/// the cache's own usage snapshot satisfies it, whatever the insert
/// sizes and tiers.
#[test]
fn prop_standing_budget_holds_after_every_insert() {
    use fbo::service::{CacheBudget, CacheTier, DecisionCache};

    for seed in 0..60u64 {
        let mut rng = Rng::new(seed);
        let cache = DecisionCache::in_memory();
        let budget = CacheBudget {
            max_bytes: Some((200 + rng.below(1500)) as u64),
            max_entries: Some(1 + rng.below(6)),
        };
        cache.set_budget(budget);
        for step in 0..30 {
            let tag = rng.below(12);
            let tier = CacheTier::ALL[rng.below(CacheTier::ALL.len())];
            let p = cache_payload(tag, rng.below(400));
            cache.insert_tier(&cache_key(tag), tier, &p).unwrap();
            let u = cache.usage();
            assert!(
                budget.admits(u.bytes, u.entries),
                "seed {seed} step {step}: usage {u:?} exceeds standing {budget:?}"
            );
        }
    }
}

// ------------------------------------------------------------------
// Interpreter value coercion invariants.
// ------------------------------------------------------------------

#[test]
fn prop_int_slot_truncates_float_slot_preserves() {
    for seed in 0..100u64 {
        let mut rng = Rng::new(seed);
        let x = (rng.next_f64() - 0.5) * 1000.0;
        let int_slot = Value::Int(0);
        let float_slot = Value::Float(0.0);
        match int_slot.coerce_like(Value::Float(x)).unwrap() {
            Value::Int(v) => assert_eq!(v, x as i64),
            other => panic!("{other:?}"),
        }
        match float_slot.coerce_like(Value::Float(x)).unwrap() {
            Value::Float(v) => assert_eq!(v, x),
            other => panic!("{other:?}"),
        }
    }
}
