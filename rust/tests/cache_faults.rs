//! Fault-injection tests for the decision cache's crash-consistency
//! story: every way an on-disk entry (or the advisory index) can rot —
//! truncation, garbage bytes, malformed-but-ours JSON, stale index rows,
//! a crash between eviction steps — must degrade to a *counted* cache
//! miss and a recompute. Never a panic, never a failed open, and never a
//! survivor that replays anything but the exact bytes it was given.

use std::path::PathBuf;

use fbo::coordinator::apps;
use fbo::patterndb::json;
use fbo::service::{
    CacheBudget, CacheKey, CacheTier, DecisionCache, OffloadService, ServiceConfig,
    DECISION_FORMAT,
};
use fbo::telemetry::TraceEvent;

const FP: &str = "00000000deadbeef";

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Fresh scratch cache directory, isolated per test and per process.
fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fbo-faulttest-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn key(tag: u32) -> CacheKey {
    CacheKey {
        source_hash: format!("{tag:016x}"),
        entry: "main".to_string(),
        db_fingerprint: FP.to_string(),
    }
}

/// Canonical form of a JSON payload — the exact bytes the pipeline's
/// report codec would produce, so byte-identity assertions are honest.
fn canon(raw: &str) -> String {
    json::to_string_pretty(&json::parse(raw).expect("test payload must be valid JSON"))
}

/// A hand-forged entry file claiming our format tag. Used to build the
/// malformed-but-ours corner of the fault matrix.
fn forged(source_hash: &str, tier: &str) -> String {
    format!(
        "{{\"format\": \"{DECISION_FORMAT}\", \"source_hash\": \"{source_hash}\", \
         \"entry\": \"main\", \"db_fingerprint\": \"{FP}\", \"tier\": \"{tier}\", \
         \"report\": {{\"x\": 1}}}}"
    )
}

// --------------------------------------------------------- fault matrix

/// Every class of on-disk rot loads as zero entries plus one counted
/// corruption — never a panic, never a failed `open`, and the damaged
/// file is left in place for inspection.
#[test]
fn fault_matrix_degrades_to_counted_misses() {
    let cases: Vec<(&str, String)> = vec![
        ("not-json", "\u{0}\u{1} definitely not json".to_string()),
        ("truncated-ours", format!("{{\"format\": \"{DECISION_FORMAT}\", \"source_hash\": \"00")),
        ("unknown-tier", forged("aaaaaaaaaaaaaaaa", "volcanic")),
        (
            "missing-report",
            format!(
                "{{\"format\": \"{DECISION_FORMAT}\", \"source_hash\": \"b\", \
                 \"entry\": \"main\", \"db_fingerprint\": \"{FP}\"}}"
            ),
        ),
        (
            "non-string-key-field",
            format!("{{\"format\": \"{DECISION_FORMAT}\", \"source_hash\": 17}}"),
        ),
    ];
    for (tag, body) in cases {
        let dir = temp_dir(&format!("matrix-{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("0badc0de0badc0de.json");
        std::fs::write(&path, body).unwrap();

        let cache =
            DecisionCache::open(&dir).unwrap_or_else(|e| panic!("{tag}: open failed {e:#}"));
        assert_eq!(cache.len(), 0, "{tag}: corrupt file must not load");
        assert_eq!(cache.stats().corrupt, 1, "{tag}: corruption must be counted");
        assert!(cache.lookup(&key(0)).is_none(), "{tag}");
        assert!(path.exists(), "{tag}: corrupt files are left in place for inspection");
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Rot degrades exactly one key: the next verification overwrites the
/// damaged file via tmp-file + rename and the entry replays again.
#[test]
fn truncated_entry_recovers_on_reinsert() {
    let dir = temp_dir("truncate-recover");
    let k = key(1);
    let payload = canon(r#"{"verdict": "gpu", "speedup": 3.25}"#);
    {
        let cache = DecisionCache::open(&dir).unwrap();
        cache.insert_tier(&k, CacheTier::Verified, &payload).unwrap();
    }
    let path = dir.join(format!("{}.json", k.file_stem()));
    let full = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, &full[..full.len() / 2]).unwrap();

    let cache = DecisionCache::open(&dir).unwrap();
    assert_eq!(cache.stats().corrupt, 1);
    assert!(cache.lookup(&k).is_none(), "truncated entry must be a miss");

    cache.insert_tier(&k, CacheTier::Verified, &payload).unwrap();
    let reopened = DecisionCache::open(&dir).unwrap();
    assert_eq!(reopened.stats().corrupt, 0, "reinsert must heal the file");
    assert_eq!(reopened.lookup(&k).as_deref(), Some(payload.as_str()), "byte-identical replay");
    std::fs::remove_dir_all(&dir).ok();
}

/// Foreign `.json` files (no format tag) are tolerated silently: not
/// loaded, not counted as corruption, and spared by `clear`.
#[test]
fn foreign_json_is_spared_and_not_counted() {
    let dir = temp_dir("foreign");
    std::fs::create_dir_all(&dir).unwrap();
    let notes = dir.join("notes.json");
    std::fs::write(&notes, "{\"note\": \"operator parking space\"}").unwrap();

    let cache = DecisionCache::open(&dir).unwrap();
    cache.insert_tier(&key(2), CacheTier::Decision, &canon(r#"{"d": 2}"#)).unwrap();
    assert_eq!(cache.len(), 1);
    assert_eq!(cache.stats().corrupt, 0, "foreign files are not corruption");

    cache.clear().unwrap();
    assert_eq!(cache.len(), 0);
    assert!(notes.exists(), "clear must spare foreign files");
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------- index vs entry files

/// The index is advisory: a row pointing at a file that no longer exists
/// (e.g. an operator deleted it by hand) is dropped on open without
/// being counted as corruption, and survivors replay byte-identically.
#[test]
fn index_rows_for_deleted_files_are_dropped() {
    let dir = temp_dir("stale-index");
    let survivor_payload = canon(r#"{"kept": true, "cost": 12.5}"#);
    {
        let cache = DecisionCache::open(&dir).unwrap();
        cache.insert_tier(&key(1), CacheTier::Verified, &survivor_payload).unwrap();
        cache.insert_tier(&key(2), CacheTier::Decision, &canon(r#"{"kept": false}"#)).unwrap();
    }
    std::fs::remove_file(dir.join(format!("{}.json", key(2).file_stem()))).unwrap();

    let cache = DecisionCache::open(&dir).unwrap();
    assert_eq!(cache.len(), 1);
    assert_eq!(cache.stats().corrupt, 0, "a stale index row is recovery, not corruption");
    assert!(cache.lookup(&key(2)).is_none());
    assert_eq!(cache.lookup(&key(1)).as_deref(), Some(survivor_payload.as_str()));
    std::fs::remove_dir_all(&dir).ok();
}

/// A destroyed index costs recency only: every entry file still loads
/// byte-identically (files are authoritative), the bad index is counted,
/// and tier priority still orders the next eviction correctly.
#[test]
fn corrupt_index_resets_recency_but_loses_no_payload() {
    let dir = temp_dir("bad-index");
    let payloads = [
        (key(1), CacheTier::Reconciled, canon(r#"{"stage": "reconciled"}"#)),
        (key(2), CacheTier::Decision, canon(r#"{"stage": "decision"}"#)),
        (key(3), CacheTier::Verified, canon(r#"{"stage": "verified"}"#)),
    ];
    {
        let cache = DecisionCache::open(&dir).unwrap();
        for (k, tier, p) in &payloads {
            cache.insert_tier(k, *tier, p).unwrap();
        }
    }
    std::fs::write(dir.join("index.json"), "!!! not an index !!!").unwrap();

    let cache = DecisionCache::open(&dir).unwrap();
    assert_eq!(cache.len(), 3, "entry files are authoritative");
    assert_eq!(cache.stats().corrupt, 1, "the unreadable index is counted");
    for (k, _, p) in &payloads {
        assert_eq!(cache.lookup(k).as_deref(), Some(p.as_str()), "byte-identical after reset");
    }

    // Recency is gone but tier priority still holds: shrinking to one
    // entry evicts reconciled and decision, never the verified evidence.
    let out = cache.gc(CacheBudget { max_bytes: None, max_entries: Some(1) }, false).unwrap();
    assert_eq!(out.entries_after, 1);
    assert_eq!(
        out.evicted.iter().map(|e| e.tier).collect::<Vec<_>>(),
        [CacheTier::Reconciled, CacheTier::Decision]
    );
    assert!(cache.lookup(&key(3)).is_some(), "verified evidence survives");
    std::fs::remove_dir_all(&dir).ok();
}

/// Crash simulation for the eviction sequence (remove victim file, then
/// rewrite index): dying between the two steps leaves a stale index row
/// and possibly an orphaned tmp file from an interrupted publish. Both
/// must cost nothing — survivors load untouched and byte-identical.
#[test]
fn crash_between_eviction_steps_costs_only_stale_index() {
    let dir = temp_dir("crash-evict");
    let survivor = canon(r#"{"measured": [1.5, 2.25], "winner": "fpga"}"#);
    {
        let cache = DecisionCache::open(&dir).unwrap();
        cache.insert_tier(&key(1), CacheTier::Reconciled, &canon(r#"{"cheap": 1}"#)).unwrap();
        cache.insert_tier(&key(2), CacheTier::Verified, &survivor).unwrap();
    }
    // The crash point: eviction removed the victim's file but died before
    // publishing the updated index (and mid-publish of some other write,
    // leaving a tmp file behind).
    std::fs::remove_file(dir.join(format!("{}.json", key(1).file_stem()))).unwrap();
    std::fs::write(dir.join(".deadbeef00000000.999.0.tmp"), "{\"half\": ").unwrap();

    let cache = DecisionCache::open(&dir).unwrap();
    assert_eq!(cache.len(), 1, "tmp files and stale rows must not load");
    assert_eq!(cache.stats().corrupt, 0);
    assert_eq!(cache.lookup(&key(2)).as_deref(), Some(survivor.as_str()));
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------- end-to-end recovery

/// Full service loop: rot every persisted artifact of a completed job,
/// restart, and the service recomputes from scratch (counting and
/// tracing each corrupt file), then replays the recomputed decision
/// byte-identically.
#[test]
fn service_recovers_from_on_disk_rot_by_recomputing() {
    let cache_dir = temp_dir("service");
    let mut cfg = ServiceConfig::new(artifacts_dir());
    cfg.cache_dir = Some(cache_dir.clone());
    cfg.workers = 1;
    cfg.verify.reps = 1;
    let src = apps::matmul_app(64);

    {
        let service = OffloadService::start(cfg.clone()).unwrap();
        assert!(!service.submit(&src, "main").wait().unwrap().from_cache);
        service.shutdown();
    }

    // Truncate every persisted artifact (decision + stage tiers).
    let mut rotted = 0u64;
    for e in std::fs::read_dir(&cache_dir).unwrap() {
        let path = e.unwrap().path();
        if path.extension().and_then(|x| x.to_str()) != Some("json")
            || path.file_name().and_then(|x| x.to_str()) == Some("index.json")
        {
            continue;
        }
        let body = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &body[..body.len() / 3]).unwrap();
        rotted += 1;
    }
    assert!(rotted >= 3, "expected decision + stage artifacts on disk, found {rotted}");

    let service = OffloadService::start(cfg).unwrap();
    let recomputed = service.submit(&src, "main").wait().unwrap();
    assert!(!recomputed.from_cache, "rotted entries must degrade to a miss");
    assert_eq!(recomputed.resumed_from, None, "every stage artifact was rotted");

    let snap = service.stats();
    assert_eq!(snap.cache_corrupt, rotted, "each rotted file counted exactly once");
    let corrupt_events = service
        .recorder()
        .records()
        .iter()
        .filter(|r| matches!(&r.event, TraceEvent::CacheCorrupt { .. }))
        .count() as u64;
    assert_eq!(corrupt_events, rotted, "each rotted file traced exactly once");

    let replay = service.submit(&src, "main").wait().unwrap();
    assert!(replay.from_cache);
    assert_eq!(replay.report_json, recomputed.report_json, "byte-identical replay after recovery");
    service.shutdown();
    std::fs::remove_dir_all(&cache_dir).ok();
}
