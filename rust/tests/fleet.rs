//! Integration tests for the measurement fleet: the golden wire fixture,
//! the failure matrix (worker death mid-batch, version mismatch, garbage
//! frames, capability gaps, drain-then-stop), and the equivalence
//! contract — fleet-verified decisions match serial ones and replay each
//! other's cache entries byte-identically.

use std::io::{BufReader, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::rc::Rc;
use std::thread::JoinHandle;

use fbo::coordinator::{apps, Coordinator, OffloadReport, SerialExecutor};
use fbo::fleet::wire::{read_frame, write_frame};
use fbo::fleet::{Capabilities, FleetEndpoint, FleetExecutor, FleetRegistry, Frame, WorkerHost};
use fbo::service::{OffloadService, ServiceConfig};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// A real fleet worker serving one TCP connection on an ephemeral port.
/// The engine opens inside the thread (PJRT state never crosses threads);
/// the listener binds here so a registry can connect before the worker
/// reaches `accept`.
fn spawn_worker(caps: Capabilities) -> (SocketAddr, JoinHandle<anyhow::Result<()>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        let host = WorkerHost::open(&artifacts_dir(), caps)?;
        let (stream, _) = listener.accept()?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        host.serve_connection(&mut reader, &mut writer)
    });
    (addr, handle)
}

/// A scripted fake worker for fault injection: the closure gets the
/// accepted connection and does whatever damage the test needs.
fn spawn_fake_worker<F>(script: F) -> (SocketAddr, JoinHandle<()>)
where
    F: FnOnce(BufReader<TcpStream>, TcpStream) + Send + 'static,
{
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        script(reader, stream);
    });
    (addr, handle)
}

fn tcp(addr: SocketAddr) -> FleetEndpoint {
    FleetEndpoint::Tcp(addr.to_string())
}

/// Run one offload through a fleet executor over `registry`, returning
/// the report and the executor (for its stats).
fn offload_via_fleet(
    c: &Coordinator,
    registry: FleetRegistry,
    src: &str,
) -> (OffloadReport, Rc<FleetExecutor>) {
    let fallback = Rc::new(SerialExecutor::new(c.engine.clone()));
    let exec = Rc::new(FleetExecutor::new(registry, fallback));
    let report = c.request(src, "main").with_executor(exec.clone()).run();
    (report.unwrap(), exec)
}

// -------------------------------------------------------- golden fixture

/// The wire format is pinned by a fixture: every frame must decode and
/// re-encode byte-identically. A failure here means `fbo-fleet-v1`
/// changed shape and mixed-version fleets would desynchronize — bump the
/// protocol constant instead.
#[test]
fn golden_wire_fixture_is_stable() {
    let fixture: &[u8] = include_bytes!("fixtures/fleet_golden.txt");
    let mut reader = BufReader::new(fixture);
    let mut rewritten: Vec<u8> = Vec::new();
    let mut names = Vec::new();
    while rewritten.len() < fixture.len() {
        let frame = read_frame(&mut reader).expect("fixture frame must decode");
        names.push(frame.name());
        write_frame(&mut rewritten, &frame).unwrap();
    }
    assert_eq!(
        names,
        ["hello", "measure-batch", "measure-result", "heartbeat", "drain", "bye"],
        "fixture must exercise every frame kind"
    );
    assert_eq!(rewritten, fixture, "round-trip must be byte-identical");
}

// ----------------------------------------------------------- equivalence

#[test]
fn two_tcp_workers_match_the_serial_decision() {
    let (addr_a, worker_a) = spawn_worker(Capabilities::default());
    let (addr_b, worker_b) = spawn_worker(Capabilities::default());

    let mut c = Coordinator::open(&artifacts_dir()).unwrap();
    c.verify.reps = 1;
    let src = apps::sensor_fusion_app(64);
    let serial = c.request(&src, "main").run().unwrap();

    let registry = FleetRegistry::connect(&[tcp(addr_a), tcp(addr_b)]);
    assert_eq!(registry.live_count(), 2, "{:?}", registry.rejected());
    let (fleet, exec) = offload_via_fleet(&c, registry, &src);

    // The fleet buys wall-clock, never a different answer: same winning
    // pattern, same backend verdict, same pattern labels in order.
    assert_eq!(fleet.outcome.best_enabled, serial.outcome.best_enabled);
    assert_eq!(fleet.backend(), serial.backend());
    let labels = |r: &OffloadReport| -> Vec<String> {
        r.outcome.tried.iter().map(|p| p.label.clone()).collect()
    };
    assert_eq!(labels(&fleet), labels(&serial));
    assert!(exec.stats().remote() > 0, "patterns must have measured remotely");
    assert_eq!(exec.stats().redeals(), 0);

    // Dropping the executor drains the registry; both workers see the
    // drain frame and exit their connection loop cleanly.
    drop(exec);
    worker_a.join().unwrap().unwrap();
    worker_b.join().unwrap().unwrap();
}

// --------------------------------------------------------- failure matrix

#[test]
fn worker_death_mid_batch_redeals_to_the_survivor() {
    // Worker A handshakes fine, then dies the moment a batch arrives.
    let (addr_a, fake) = spawn_fake_worker(|mut reader, mut stream| {
        write_frame(
            &mut stream,
            &Frame::Hello {
                protocol: fbo::fleet::PROTOCOL.to_string(),
                caps: Capabilities::default(),
            },
        )
        .unwrap();
        let _ = read_frame(&mut reader); // the measure-batch
        // Dropping both halves closes the connection mid-batch.
    });
    let (addr_b, survivor) = spawn_worker(Capabilities::default());

    let mut c = Coordinator::open(&artifacts_dir()).unwrap();
    c.verify.reps = 1;
    let src = apps::matmul_app(64);
    let serial = c.request(&src, "main").run().unwrap();

    let registry = FleetRegistry::connect(&[tcp(addr_a), tcp(addr_b)]);
    assert_eq!(registry.live_count(), 2, "{:?}", registry.rejected());
    let (fleet, exec) = offload_via_fleet(&c, registry, &src);

    assert_eq!(fleet.outcome.best_enabled, serial.outcome.best_enabled);
    assert!(exec.stats().redeals() >= 1, "the dead worker's batch must re-deal");
    let reg = exec.registry();
    assert_eq!(reg.live_count(), 1, "the dead worker stays dead");
    assert!(!reg.workers()[0].is_alive());
    assert!(reg.workers()[1].is_alive());

    drop(exec);
    fake.join().unwrap();
    survivor.join().unwrap().unwrap();
}

#[test]
fn dead_tcp_worker_reconnects_on_a_later_deal() {
    use std::cell::Cell;
    use std::sync::Arc;

    use fbo::fleet::FleetTelemetry;
    use fbo::telemetry::{Registry, TraceEvent, TraceRecorder};

    // One listener, two connections: the first handshakes and then hangs
    // up on its first batch (worker death); the second — the scheduler's
    // re-dial — lands on a real worker host that serves to completion.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handle = std::thread::spawn(move || -> anyhow::Result<()> {
        {
            let (stream, _) = listener.accept()?;
            let mut reader = BufReader::new(stream.try_clone()?);
            let mut writer = stream;
            write_frame(
                &mut writer,
                &Frame::Hello {
                    protocol: fbo::fleet::PROTOCOL.to_string(),
                    caps: Capabilities::default(),
                },
            )?;
            let _ = read_frame(&mut reader); // the measure-batch
        }
        let host = WorkerHost::open(&artifacts_dir(), Capabilities::default())?;
        let (stream, _) = listener.accept()?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        host.serve_connection(&mut reader, &mut writer)
    });

    let mut c = Coordinator::open(&artifacts_dir()).unwrap();
    c.verify.reps = 1;
    let src = apps::matmul_app(64);
    let serial = c.request(&src, "main").run().unwrap();

    let registry = FleetRegistry::connect(&[tcp(addr)]);
    assert_eq!(registry.live_count(), 1, "{:?}", registry.rejected());
    let metrics = Arc::new(Registry::new());
    let recorder = Arc::new(TraceRecorder::new(1024));
    let trace = Rc::new(Cell::new(7));
    let fallback = Rc::new(SerialExecutor::new(c.engine.clone()));
    let exec = Rc::new(
        FleetExecutor::new(registry, fallback)
            .with_telemetry(FleetTelemetry::new(metrics.clone(), recorder.clone(), trace)),
    );

    // First request: the worker dies mid-batch and the measurements fall
    // back locally (or to the revived link, if the search deals again).
    let first = c.request(&src, "main").with_executor(exec.clone()).run().unwrap();
    assert_eq!(first.outcome.best_enabled, serial.outcome.best_enabled);

    // Second request: the deal re-dials the endpoint, revives the slot,
    // and measures remotely again.
    let second = c.request(&src, "main").with_executor(exec.clone()).run().unwrap();
    assert_eq!(second.outcome.best_enabled, serial.outcome.best_enabled);
    assert_eq!(exec.registry().live_count(), 1, "the endpoint must be revived");
    assert!(exec.stats().remote() > 0, "the revived worker measured patterns");
    assert!(
        recorder.records().iter().any(|r| matches!(
            &r.event,
            TraceEvent::FleetReconnect { ok: true, attempt, .. } if *attempt >= 1
        )),
        "a successful fleet-reconnect event must be traced"
    );

    drop(exec);
    handle.join().unwrap().unwrap();
}

#[test]
fn version_mismatch_is_rejected_at_connect() {
    let (addr, fake) = spawn_fake_worker(|mut reader, mut stream| {
        write_frame(
            &mut stream,
            &Frame::Hello { protocol: "fbo-fleet-v0".to_string(), caps: Capabilities::default() },
        )
        .unwrap();
        // The registry answers a version mismatch with bye, then closes.
        assert!(matches!(read_frame(&mut reader), Ok(Frame::Bye)));
    });

    let registry = FleetRegistry::connect(&[tcp(addr)]);
    assert_eq!(registry.live_count(), 0);
    assert_eq!(registry.rejected().len(), 1);
    assert!(
        registry.rejected()[0].contains("speaks protocol \"fbo-fleet-v0\""),
        "{:?}",
        registry.rejected()
    );
    fake.join().unwrap();
}

#[test]
fn garbage_frames_kill_one_worker_not_the_registry() {
    // Worker A handshakes fine, then answers its first batch with bytes
    // that are not a frame.
    let (addr_a, fake) = spawn_fake_worker(|mut reader, mut stream| {
        write_frame(
            &mut stream,
            &Frame::Hello {
                protocol: fbo::fleet::PROTOCOL.to_string(),
                caps: Capabilities::default(),
            },
        )
        .unwrap();
        let _ = read_frame(&mut reader); // the measure-batch
        stream.write_all(b"%%% this is not a frame %%%\n").unwrap();
        let _ = stream.flush();
    });
    let (addr_b, survivor) = spawn_worker(Capabilities::default());

    let mut c = Coordinator::open(&artifacts_dir()).unwrap();
    c.verify.reps = 1;
    let src = apps::fft_app_lib(64);
    let serial = c.request(&src, "main").run().unwrap();

    let registry = FleetRegistry::connect(&[tcp(addr_a), tcp(addr_b)]);
    assert_eq!(registry.live_count(), 2, "{:?}", registry.rejected());
    let (fleet, exec) = offload_via_fleet(&c, registry, &src);

    // The desynchronized connection is dropped and its batch re-dealt;
    // the decision is unaffected.
    assert_eq!(fleet.outcome.best_enabled, serial.outcome.best_enabled);
    assert!(exec.stats().redeals() >= 1);
    assert_eq!(exec.registry().live_count(), 1);

    drop(exec);
    fake.join().unwrap();
    survivor.join().unwrap().unwrap();
}

#[test]
fn capability_gaps_fall_back_to_the_local_executor() {
    // A worker that can measure nothing offloaded: only the all-CPU
    // baseline (which needs no capability) may be dealt to it; every
    // GPU/FPGA pattern must measure locally, concurrently with it.
    let caps = Capabilities { gpu: false, fpga: false, ..Capabilities::default() };
    let (addr, worker) = spawn_worker(caps);

    let mut c = Coordinator::open(&artifacts_dir()).unwrap();
    c.verify.reps = 1;
    let src = apps::matmul_app(64);
    let serial = c.request(&src, "main").run().unwrap();

    let registry = FleetRegistry::connect(&[tcp(addr)]);
    assert_eq!(registry.live_count(), 1, "{:?}", registry.rejected());
    let (fleet, exec) = offload_via_fleet(&c, registry, &src);

    assert_eq!(fleet.outcome.best_enabled, serial.outcome.best_enabled);
    assert!(exec.stats().local() >= 1, "offloaded patterns have no capable worker");
    assert!(exec.stats().remote() >= 1, "the baseline still measures remotely");
    assert_eq!(exec.stats().redeals(), 0, "a capability gap is not a failure");

    drop(exec);
    worker.join().unwrap().unwrap();
}

#[test]
fn drain_then_stop_lets_workers_exit_cleanly() {
    let (addr, worker) = spawn_worker(Capabilities::default());
    let mut registry = FleetRegistry::connect(&[tcp(addr)]);
    assert_eq!(registry.live_count(), 1, "{:?}", registry.rejected());

    // Drain without ever dealing a batch: the worker still sees the
    // drain frame, replies bye, and its serve loop returns Ok.
    registry.drain();
    assert_eq!(registry.live_count(), 0);
    worker.join().unwrap().unwrap();

    // Idempotent — a second drain (and the Drop impl after it) is a no-op.
    registry.drain();
}

// ----------------------------------------------- stdio fleet, end to end

fn stdio_endpoint() -> String {
    format!(
        "stdio:{} worker --stdio --artifacts {}",
        env!("CARGO_BIN_EXE_fbo"),
        artifacts_dir().display()
    )
}

/// The bench-gated invariant, as a test: a service whose measurements ran
/// on spawned child workers replays a locally-verified decision
/// byte-identically, and a cold-cache fleet run lands on the same
/// decision.
#[test]
fn stdio_fleet_replays_serial_decisions_byte_identically() {
    let dir = std::env::temp_dir().join(format!("fbo-fleettest-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = ServiceConfig::new(artifacts_dir());
    cfg.cache_dir = Some(dir.clone());
    cfg.workers = 1;
    cfg.verify.reps = 1;
    let src = apps::lu_app_lib(64);

    // Verify locally and cache the decision.
    let serial = {
        let service = OffloadService::start(cfg.clone()).unwrap();
        let done = service.submit(&src, "main").wait().unwrap();
        assert!(!done.from_cache);
        done
    };

    // A two-child stdio fleet over the same cache: the endpoint list is
    // not part of any fingerprint, so the local decision replays
    // byte-identically without spinning up a single measurement.
    let mut fleet_cfg = cfg.clone();
    fleet_cfg.fleet = vec![stdio_endpoint(), stdio_endpoint()];
    let service = OffloadService::start(fleet_cfg).unwrap();
    let replayed = service.submit(&src, "main").wait().unwrap();
    assert!(replayed.from_cache, "fleet config must not shift any fingerprint");
    assert_eq!(replayed.report_json, serial.report_json, "byte-identical replay");

    // Cold the cache and re-verify through the children: same decision.
    service.cache().clear().unwrap();
    let fresh = service.submit(&src, "main").wait().unwrap();
    assert!(!fresh.from_cache);
    assert_eq!(fresh.report.outcome.best_enabled, serial.report.outcome.best_enabled);
    assert_eq!(fresh.report.backend(), serial.report.backend());

    service.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
