//! Integration tests for the telemetry subsystem: the golden JSONL
//! schema, trace semantics of a full pipeline run and of cache-resumed
//! jobs, the passivity invariant (traced services replay untraced
//! decisions byte-identically), and the Prometheus scrape endpoint.

use std::io::{Read as _, Write as _};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;

use fbo::coordinator::{apps, BackendPolicy, Coordinator, PowerPolicy, Stage};
use fbo::service::{OffloadService, ServiceConfig};
use fbo::telemetry::{MetricsServer, TraceEvent, TraceObserver, TraceRecord, TraceRecorder};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Per-test config with an isolated cache dir under the temp root.
fn test_config(tag: &str) -> (ServiceConfig, PathBuf) {
    let dir = std::env::temp_dir().join(format!("fbo-telemetrytest-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = ServiceConfig::new(artifacts_dir());
    cfg.cache_dir = Some(dir.clone());
    cfg.workers = 2;
    cfg.verify.reps = 1;
    (cfg, dir)
}

/// Stage names of the spans in `records`, in recording order.
fn span_names(records: &[TraceRecord]) -> Vec<&'static str> {
    records
        .iter()
        .filter_map(|r| match &r.event {
            TraceEvent::StageCompleted { stage, .. } => Some(stage.as_str()),
            _ => None,
        })
        .collect()
}

// ------------------------------------------------------- golden schema

/// The JSONL wire format is pinned by a fixture: every line must decode,
/// re-encode byte-identically, and carry the expected discriminator. A
/// failure here means the schema changed and downstream consumers
/// (scripts tailing `--trace-out` files) would break.
#[test]
fn golden_jsonl_schema_is_stable() {
    let fixture = include_str!("fixtures/trace_golden.jsonl");
    let mut names = Vec::new();
    for line in fixture.lines() {
        let rec = TraceRecord::from_jsonl_line(line).expect(line);
        assert_eq!(rec.to_jsonl_line(), line, "round-trip must be byte-identical");
        names.push(rec.event.name());
    }
    assert_eq!(
        names,
        [
            "request-started",
            "cache",
            "stage",
            "pattern",
            "power",
            "verdict",
            "resumed",
            "dispatch",
            "request-completed",
            "cache-corrupt",
            "fleet",
            "estimate",
            "fleet-reconnect",
            "residency",
        ],
        "fixture must exercise every event variant"
    );
}

// ------------------------------------------------------ CLI-style trace

#[test]
fn cli_trace_carries_spans_and_decision_events() {
    let mut c = Coordinator::open(&artifacts_dir()).unwrap();
    c.verify.reps = 1;
    let src = apps::matmul_app(64);

    let recorder = Arc::new(TraceRecorder::new(4096));
    let obs = Arc::new(TraceObserver::begin(&recorder, "main"));
    let report = c.request(&src, "main").with_observer(obs.clone()).run().unwrap();
    obs.complete(false, true);

    let records = recorder.records();
    assert!(records.iter().all(|r| r.trace == obs.trace_id()));
    let seqs: Vec<u64> = records.iter().map(|r| r.seq).collect();
    assert!(seqs.windows(2).all(|w| w[0] < w[1]), "seq must be monotonic: {seqs:?}");

    // One span per pipeline stage, in pipeline order.
    assert_eq!(
        span_names(&records),
        ["parse", "discover", "reconcile", "estimate", "verify", "power-score", "arbitrate"]
    );

    // Step 3 reported every measurement: the all-CPU baseline first, then
    // one event per tried pattern.
    let patterns: Vec<&str> = records
        .iter()
        .filter_map(|r| match &r.event {
            TraceEvent::PatternMeasured { label, .. } => Some(label.as_str()),
            _ => None,
        })
        .collect();
    assert_eq!(patterns.first(), Some(&"all-CPU"));
    assert_eq!(patterns.len(), 1 + report.outcome.tried.len());

    // Step 3b reported its verdicts and the power stage its scores.
    assert!(records.iter().any(|r| matches!(
        &r.event,
        TraceEvent::ArbitrationVerdict { policy, .. } if policy == "auto"
    )));
    assert!(records.iter().any(|r| matches!(&r.event, TraceEvent::PowerScored { .. })));

    // The request envelope brackets everything.
    assert_eq!(records.first().unwrap().event.name(), "request-started");
    assert_eq!(records.last().unwrap().event.name(), "request-completed");
}

// ------------------------------------------------------------ passivity

/// Two fresh pipeline runs are never byte-identical (measurements are
/// real wall-clock), so passivity is asserted the way it matters in
/// operation: telemetry config shifts no fingerprint, hence a traced
/// service replays an untraced service's decision byte-for-byte.
#[test]
fn traced_service_replays_untraced_decisions_byte_identically() {
    let (mut cfg, dir) = test_config("passive");
    let src = apps::lu_app_lib(64);

    let untraced_json = {
        let service = OffloadService::start(cfg.clone()).unwrap();
        let done = service.submit(&src, "main").wait().unwrap();
        assert!(!done.from_cache);
        done.report_json
    };

    let trace_path = dir.join("trace.jsonl");
    cfg.telemetry.trace_out = Some(trace_path.clone());
    let service = OffloadService::start(cfg).unwrap();
    let done = service.submit(&src, "main").wait().unwrap();
    assert!(done.from_cache, "telemetry must not shift any fingerprint");
    assert_eq!(done.report_json, untraced_json, "byte-identical replay under tracing");

    let records = service.recorder().records();
    assert!(records.iter().any(|r| matches!(
        &r.event,
        TraceEvent::CacheProbe { tier, hit: true } if tier == "decision"
    )));
    assert!(records
        .iter()
        .any(|r| r.event == TraceEvent::RequestCompleted { from_cache: true, ok: true }));

    // The sink mirrors the ring line-for-line and every line decodes.
    let recorder = service.recorder().clone();
    service.shutdown();
    assert_eq!(recorder.dropped(), 0);
    assert_eq!(recorder.sink_errors(), 0);
    let sink = std::fs::read_to_string(&trace_path).unwrap();
    let lines: Vec<&str> = sink.lines().collect();
    assert_eq!(lines.len(), recorder.len());
    for line in lines {
        TraceRecord::from_jsonl_line(line).expect(line);
    }

    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------- resume semantics

/// A job resumed from a cached stage artifact traces spans only for the
/// stages it actually re-ran, plus an explicit `resumed` marker naming
/// the tier it resumed from.
#[test]
fn resumed_jobs_trace_only_the_rerun_stages() {
    let (cfg, dir) = test_config("resume");
    let src = apps::fft_app_lib(64);

    // Scratch run populates the decision and stage caches.
    {
        let service = OffloadService::start(cfg.clone()).unwrap();
        assert!(!service.submit(&src, "main").wait().unwrap().from_cache);
    }

    // A power-policy change resumes from the Verified artifact: the trace
    // carries spans only for power-score + arbitrate, never verify.
    {
        let mut ppw = cfg.clone();
        ppw.power_policy = PowerPolicy::PerfPerWatt;
        let service = OffloadService::start(ppw).unwrap();
        let done = service.submit(&src, "main").wait().unwrap();
        assert_eq!(done.resumed_from, Some(Stage::Verify));

        let records: Vec<TraceRecord> = service
            .recorder()
            .records()
            .into_iter()
            .filter(|r| r.trace == done.id)
            .collect();
        assert_eq!(span_names(&records), ["power-score", "arbitrate"]);
        assert!(records.iter().any(|r| r.event == TraceEvent::Resumed { from: Stage::Verify }));
        assert!(records.iter().any(|r| matches!(
            &r.event,
            TraceEvent::CacheProbe { tier, hit: false } if tier == "decision"
        )));
        assert!(records.iter().any(|r| matches!(
            &r.event,
            TraceEvent::CacheProbe { tier, hit: true } if tier == "verified"
        )));
        service.shutdown();
    }

    // Deeper still: with the PowerScored artifact now persisted, a
    // backend retarget re-runs (and traces) arbitration alone.
    {
        let mut ppw = cfg;
        ppw.power_policy = PowerPolicy::PerfPerWatt;
        ppw.backend_policy = BackendPolicy::Gpu;
        let service = OffloadService::start(ppw).unwrap();
        let done = service.submit(&src, "main").wait().unwrap();
        assert_eq!(done.resumed_from, Some(Stage::PowerScore));

        let records: Vec<TraceRecord> = service
            .recorder()
            .records()
            .into_iter()
            .filter(|r| r.trace == done.id)
            .collect();
        assert_eq!(span_names(&records), ["arbitrate"]);
        assert!(records
            .iter()
            .any(|r| r.event == TraceEvent::Resumed { from: Stage::PowerScore }));
        service.shutdown();
    }

    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------------ scrape endpoint

fn http_get(addr: std::net::SocketAddr, path: &str) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(stream, "GET {path} HTTP/1.1\r\nHost: fbo\r\nConnection: close\r\n\r\n").unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).unwrap();
    response
}

#[test]
fn metrics_endpoint_serves_prometheus_counters() {
    let (cfg, dir) = test_config("prom");
    let service = OffloadService::start(cfg).unwrap();

    // Two identical jobs: the pipeline runs once, the second replays from
    // the decision tier (identical keys serialize on one worker queue).
    let src = apps::lu_app_lib(64);
    let jobs = vec![(src.clone(), "main".to_string()), (src, "main".to_string())];
    for result in service.run_batch(&jobs) {
        result.unwrap();
    }

    let handle = service.metrics();
    let server = MetricsServer::start("127.0.0.1:0", move || handle.render_prometheus()).unwrap();

    let response = http_get(server.addr(), "/metrics");
    assert!(response.starts_with("HTTP/1.1 200 OK"), "{response}");
    assert!(response.contains("text/plain; version=0.0.4"), "{response}");
    assert!(response.contains("fbo_jobs_completed_total 2"), "{response}");
    assert!(
        response.contains("fbo_cache_lookups_total{result=\"hit\",tier=\"decision\"} 1"),
        "{response}"
    );
    assert!(
        response.contains("fbo_cache_lookups_total{result=\"miss\",tier=\"decision\"} 1"),
        "{response}"
    );
    assert!(response.contains("fbo_stage_seconds_count{stage=\"verify\"} 1"), "{response}");
    assert!(response.contains("fbo_stage_seconds_bucket{stage=\"verify\",le=\""), "{response}");
    assert!(response.contains("fbo_job_seconds_count 2"), "{response}");

    let missing = http_get(server.addr(), "/nope");
    assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");

    server.stop();
    service.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
