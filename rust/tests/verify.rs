//! Integration tests for the Step-3 pattern search: measurement
//! accounting regressions (traffic divisor, externals-after-reset),
//! search edge cases (no blocks, every pattern failing), and the
//! serial-vs-pooled executor equivalence the parallel verification
//! feature is built on.

use std::path::PathBuf;
use std::rc::Rc;

use fbo::coordinator::verify;
use fbo::coordinator::{apps, Coordinator, VerifyConfig};
use fbo::interp::{Interp, Value};
use fbo::parser;
use fbo::service::{MeasurePool, OffloadService, ServiceConfig};
use fbo::transform::PlannedReplacement;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn coordinator() -> Coordinator {
    let mut c = Coordinator::open(&artifacts_dir()).unwrap();
    c.verify.reps = 1;
    c
}

/// The accepted replacement plans + library-linked program of an app —
/// the exact inputs `search_patterns` consumes inside the Verify stage.
fn verify_inputs(c: &Coordinator, src: &str) -> (parser::Program, Vec<PlannedReplacement>) {
    let req = c.request(src, "main");
    let reconciled = req.parse().unwrap().discover(&req).unwrap().reconcile(&req).unwrap();
    let accepted = reconciled.accepted();
    let prog = parser::parse(src).unwrap();
    let linked = c.link_cpu_libraries(&prog).unwrap();
    (linked, accepted)
}

// ------------------------------------------------ measurement accounting

#[test]
fn externals_survive_reset_run_state() {
    // The pooled executor re-runs interpreters aggressively; the verify
    // loop re-installs externals after every reset and this pins the
    // underlying contract: a reset never strands an external dispatch.
    let prog =
        parser::parse("double main() { double a[2]; a[0] = 21.0; return __fb_twice(a); }").unwrap();
    let mut m = Interp::new(&prog).unwrap();
    m.set_external(
        "__fb_twice",
        Rc::new(|args: &[Value]| {
            let s = args[0].as_arr()?;
            Ok(Value::Float(s.get(0)? * 2.0))
        }),
    );
    let v1 = m.run("main", &[]).unwrap().as_num().unwrap();
    assert_eq!(v1, 42.0);
    m.reset_run_state().unwrap();
    assert!(
        m.externals.contains_key("__fb_twice"),
        "reset_run_state clears run state only, never the installed externals"
    );
    let v2 = m.run("main", &[]).unwrap().as_num().unwrap();
    assert_eq!(v2, 42.0, "the external must still dispatch after a reset");
}

#[test]
fn traffic_divisor_counts_every_engine_dispatching_run() {
    // Regression for the per-run DeviceTraffic divisor: with reps == 0
    // (clamped to one measured run) and warmup > 0, the divisor must be
    // the exact number of engine-dispatching runs — the per-run traffic
    // then equals a plain single-run measurement's, and the FPGA
    // arbitration sees the same working set either way.
    let c = coordinator();
    let src = apps::fft_app_lib(64);
    let (linked, accepted) = verify_inputs(&c, &src);
    assert!(!accepted.is_empty());
    let mut enabled = vec![false; accepted.len()];
    enabled[0] = true;

    let clamped = VerifyConfig { reps: 0, warmup: 2, ..VerifyConfig::default() };
    let m0 = verify::measure_pattern(
        &linked,
        "main",
        &accepted,
        &enabled,
        &c.engine,
        &clamped,
        "reps0",
    )
    .unwrap();
    assert_eq!(m0.time.reps, 1, "measure clamps reps=0 to one measured run");

    let single = VerifyConfig { reps: 1, warmup: 0, ..VerifyConfig::default() };
    let m1 = verify::measure_pattern(
        &linked,
        "main",
        &accepted,
        &enabled,
        &c.engine,
        &single,
        "reps1",
    )
    .unwrap();

    // fft_app_lib dispatches the artifact exactly once per run.
    assert_eq!(m1.traffic.dispatches, 1);
    assert_eq!(m0.traffic.dispatches, m1.traffic.dispatches, "per-run dispatches must agree");
    assert_eq!(m0.traffic.bytes_in, m1.traffic.bytes_in, "per-run bytes_in must agree");
    assert_eq!(m0.traffic.bytes_out, m1.traffic.bytes_out, "per-run bytes_out must agree");
    assert!(m0.traffic.device_secs > 0.0);
}

// ------------------------------------------------------ search edge cases

#[test]
fn zero_replaceable_blocks_reduce_to_the_baseline() {
    let c = coordinator();
    let prog = parser::parse(&apps::stencil_app(16)).unwrap();
    let outcome =
        verify::search_patterns(&prog, "main", &[], &c.engine, &c.verify).unwrap();
    assert!(outcome.tried.is_empty());
    assert!(outcome.best_enabled.is_empty());
    assert!((outcome.best_speedup - 1.0).abs() < 1e-9);
    assert_eq!(outcome.best_time.median, outcome.baseline.median);
}

#[test]
fn all_patterns_failing_falls_back_to_the_baseline() {
    let c = coordinator();
    let src = apps::sensor_fusion_app(64);
    let (linked, mut accepted) = verify_inputs(&c, &src);
    assert_eq!(accepted.len(), 3, "sensor-fusion app must discover three blocks");
    for plan in &mut accepted {
        plan.replacement.artifact = "no_such_artifact".to_string();
    }
    let outcome =
        verify::search_patterns(&linked, "main", &accepted, &c.engine, &c.verify).unwrap();
    assert_eq!(outcome.tried.len(), 3, "every failed pattern is still recorded");
    for p in &outcome.tried {
        assert!(p.label.contains("[failed:"), "{}", p.label);
        assert_eq!(p.speedup, 0.0);
        assert!(!p.output_ok);
    }
    assert_eq!(outcome.best_enabled, vec![false, false, false]);
    assert!((outcome.best_speedup - 1.0).abs() < 1e-9);
}

// -------------------------------------------- serial / pooled equivalence

#[test]
fn serial_and_pooled_executors_agree_on_the_multi_block_fixture() {
    let src = apps::sensor_fusion_app(64);

    let serial = coordinator();
    let serial_report = serial.offload(&src, "main").unwrap();
    assert!(
        serial_report.outcome.tried.len() >= 4,
        "3 per-block patterns + combined-winners, got {:?}",
        serial_report.outcome.tried.iter().map(|p| &p.label).collect::<Vec<_>>()
    );

    let mut pooled = coordinator();
    let pool = MeasurePool::start(&artifacts_dir(), 2).unwrap();
    pooled.executor = Some(Rc::new(pool.executor(pooled.engine.clone(), 3)));
    let pooled_report = pooled.offload(&src, "main").unwrap();

    assert_eq!(
        serial_report.outcome.best_enabled, pooled_report.outcome.best_enabled,
        "executors must pick the same winning pattern"
    );
    assert_eq!(
        serial_report.outcome.tried.iter().map(|p| &p.label).collect::<Vec<_>>(),
        pooled_report.outcome.tried.iter().map(|p| &p.label).collect::<Vec<_>>(),
        "tried order must be identical"
    );
    assert_eq!(
        serial_report.outcome.tried.iter().map(|p| p.output_ok).collect::<Vec<_>>(),
        pooled_report.outcome.tried.iter().map(|p| p.output_ok).collect::<Vec<_>>(),
    );
    assert!(serial_report.best_speedup() > 1.0);
    assert!(pooled_report.best_speedup() > 1.0);
}

#[test]
fn pooled_service_replays_serial_decisions_byte_identically() {
    let dir = std::env::temp_dir().join(format!("fbo-verifytest-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = ServiceConfig::new(artifacts_dir());
    cfg.cache_dir = Some(dir.clone());
    cfg.verify.reps = 1;
    cfg.workers = 2;
    let src = apps::sensor_fusion_app(64);

    // Verify serially and cache the decision.
    let serial_json = {
        let service = OffloadService::start(cfg.clone()).unwrap();
        let done = service.submit(&src, "main").wait().unwrap();
        assert!(!done.from_cache);
        done.report_json
    };

    // A pooled service over the same cache: the executor is not part of
    // any fingerprint, so the serial decision replays byte-identically.
    let mut pooled_cfg = cfg.clone();
    pooled_cfg.workers = 3;
    pooled_cfg.verify_parallel = 3;
    let service = OffloadService::start(pooled_cfg).unwrap();
    let replayed = service.submit(&src, "main").wait().unwrap();
    assert!(replayed.from_cache, "pooled request must hit the serial decision");
    assert_eq!(replayed.report_json, serial_json, "cached replay must be byte-identical");

    // Cold the cache and re-verify through the pool: the measurement
    // sub-jobs fan out to the idle sibling workers and the decision is
    // structurally the same one the serial search produced.
    service.cache().clear().unwrap();
    let fresh = service.submit(&src, "main").wait().unwrap();
    assert!(!fresh.from_cache);
    assert_eq!(fresh.report.outcome.best_enabled, replayed.report.outcome.best_enabled);
    let stats = service.stats();
    assert!(
        stats.patterns_parallel > 0,
        "pooled verify must fan patterns to siblings: {}",
        stats.render()
    );
    assert!(stats.patterns_serial > 0, "the verifying worker measures its own share too");

    service.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn concurrent_pooled_searches_do_not_deadlock() {
    // Two workers, both inside the Verify stage at once, fanning pattern
    // measurements to each other: the waiting worker must keep servicing
    // its own queue's measurement sub-jobs or this test hangs.
    let mut cfg = ServiceConfig::new(artifacts_dir());
    cfg.persist = false;
    cfg.workers = 2;
    cfg.verify_parallel = 2;
    cfg.verify.reps = 1;
    let service = OffloadService::start(cfg).unwrap();

    let jobs: Vec<(String, String)> = [
        apps::sensor_fusion_app(64),
        apps::fft_app_lib(64),
        apps::lu_app_lib(64),
        apps::matmul_app(64),
    ]
    .into_iter()
    .map(|src| (src, "main".to_string()))
    .collect();
    let results = service.run_batch(&jobs);
    assert_eq!(results.len(), 4);
    for r in results {
        let done = r.expect("every job completes despite mutual fan-out");
        assert!(done.report.best_speedup() >= 1.0);
    }
    let stats = service.stats();
    assert_eq!(stats.completed, 4);
    assert_eq!(stats.failed, 0);
    service.shutdown();
}
