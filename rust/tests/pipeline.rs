//! Integration tests for the staged pipeline API: stage-by-stage runs
//! must match the one-shot `Coordinator::offload` wrapper, every stage
//! artifact must serialize and resume in isolation, structured errors
//! must carry the failing stage and its partial artifact, and stage
//! observers must see every stage.

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use fbo::coordinator::{
    apps, flow, Backend, BackendPolicy, Coordinator, OffloadError, OffloadReport, Stage,
    StageObserver, Verified,
};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn coordinator() -> Coordinator {
    let mut c = Coordinator::open(&artifacts_dir()).expect("run `make artifacts` first");
    c.verify.reps = 1;
    c
}

/// The decision content of a report — everything except the measured
/// wall-clocks, which differ between any two runs by nature.
fn decision_of(r: &OffloadReport) -> String {
    format!(
        "entry:{} callees:{:?} blocks:{:?} enabled:{:?} labels:{:?} ok:{:?} \
         backends:{:?} overall:{} policy:{} source:{}",
        r.entry,
        r.external_callees,
        r.blocks
            .iter()
            .map(|b| {
                (
                    format!("{:?}", b.via),
                    b.plan.site.label(),
                    format!("{:?}", b.plan.reconciliation),
                )
            })
            .collect::<Vec<_>>(),
        r.outcome.best_enabled,
        r.outcome.tried.iter().map(|p| p.label.clone()).collect::<Vec<_>>(),
        r.outcome.tried.iter().map(|p| p.output_ok).collect::<Vec<_>>(),
        r.arbitration.blocks.iter().map(|b| b.backend.as_str()).collect::<Vec<_>>(),
        r.arbitration.backend.as_str(),
        r.arbitration.policy.as_str(),
        r.transformed_source,
    )
}

// ------------------------------------------------- staged == one-shot

#[test]
fn staged_run_matches_one_shot_offload() {
    let c = coordinator();
    let src = apps::fft_app_lib(64);

    // Drive the pipeline stage by stage...
    let req = c.request(&src, "main");
    let parsed = req.parse().unwrap();
    let discovered = parsed.discover(&req).unwrap();
    assert!(!discovered.candidates.is_empty(), "fft2d must be discovered");
    let reconciled = discovered.reconcile(&req).unwrap();
    assert_eq!(reconciled.blocks.len(), discovered.candidates.len());
    let verified = reconciled.verify(&req).unwrap();
    assert!(verified.outcome.best_speedup > 1.0);
    let arbitrated = verified.arbitrate(&req).unwrap();
    let staged = arbitrated.report();

    // ...and through the compatibility wrapper: the decision must be
    // identical (timings are wall-clock and differ between runs).
    let one_shot = c.offload(&src, "main").unwrap();
    assert_eq!(decision_of(&staged), decision_of(&one_shot));

    // The staged report is the real thing end to end: it serializes
    // through the same codec the decision cache uses.
    let encoded = fbo::coordinator::report_json::report_to_string(&staged);
    let back = fbo::coordinator::report_json::report_from_str(&encoded).unwrap();
    assert_eq!(decision_of(&back), decision_of(&staged));
}

// ------------------------------------------------- serialize + resume

#[test]
fn every_stage_artifact_serializes_and_resumes() {
    let c = coordinator();
    let src = apps::lu_app_lib(64);
    let req = c.request(&src, "main");

    let parsed = req.parse().unwrap();
    let parsed2 = fbo::coordinator::Parsed::from_json_str(&parsed.to_json_string()).unwrap();
    assert_eq!(parsed2.source, parsed.source);

    let discovered = parsed2.discover(&req).unwrap();
    let discovered2 =
        fbo::coordinator::Discovered::from_json_str(&discovered.to_json_string()).unwrap();
    assert_eq!(discovered2.candidates.len(), discovered.candidates.len());

    let reconciled = discovered2.reconcile(&req).unwrap();
    let reconciled2 =
        fbo::coordinator::Reconciled::from_json_str(&reconciled.to_json_string()).unwrap();
    assert_eq!(reconciled2.blocks.len(), reconciled.blocks.len());

    let estimated = reconciled2.estimate(&req).unwrap();
    let estimated2 =
        fbo::coordinator::Estimated::from_json_str(&estimated.to_json_string()).unwrap();
    assert_eq!(estimated2.estimates.blocks.len(), estimated.estimates.blocks.len());
    assert_eq!(
        estimated2.estimates.prune_mask(),
        vec![false; estimated.estimates.blocks.len()],
        "the default policy never prunes"
    );

    let verified = estimated2.verify(&req).unwrap();
    let saved = verified.to_json_string();
    let verified2 = Verified::from_json_str(&saved).unwrap();
    assert_eq!(verified2.to_json_string(), saved, "stage codec must be byte-stable");

    let scored = verified2.power_score(&req).unwrap();
    let saved_scores = scored.to_json_string();
    let scored2 = fbo::coordinator::PowerScored::from_json_str(&saved_scores).unwrap();
    assert_eq!(scored2.to_json_string(), saved_scores, "power stage codec must be byte-stable");
    assert_eq!(scored2.scores.blocks.len(), verified.outcome.tried.len());

    let arbitrated = scored2.arbitrate(&req).unwrap();
    let arbitrated2 =
        fbo::coordinator::Arbitrated::from_json_str(&arbitrated.to_json_string()).unwrap();
    assert_eq!(arbitrated2.transformed_source, arbitrated.transformed_source);
    assert!(arbitrated2.report().best_speedup() > 1.0);
}

#[test]
fn resuming_a_verified_artifact_under_a_new_target_changes_the_outcome() {
    // The inspect-and-resume loop of examples/staged_pipeline.rs, under
    // test: verify once, arbitrate twice under different targets. The
    // measurements are shared; only arbitration re-runs.
    let c = coordinator();
    let src = apps::lu_app_lib(64);
    let req = c.request(&src, "main");
    let saved = req
        .parse()
        .unwrap()
        .discover(&req)
        .unwrap()
        .reconcile(&req)
        .unwrap()
        .verify(&req)
        .unwrap()
        .to_json_string();

    let gpu_req = c.request(&src, "main").with_target(BackendPolicy::Gpu);
    let gpu = Verified::from_json_str(&saved).unwrap().arbitrate(&gpu_req).unwrap();
    assert_eq!(gpu.report().backend(), Backend::Gpu);
    assert_eq!(gpu.arbitration.simulated_hours, 0.0);

    let fpga_req = c.request(&src, "main").with_target(BackendPolicy::Fpga);
    let fpga = Verified::from_json_str(&saved).unwrap().arbitrate(&fpga_req).unwrap();
    assert_eq!(fpga.report().backend(), Backend::Fpga);
    assert!(fpga.arbitration.simulated_hours >= 3.0, "forced FPGA pays the compile");

    // Same verified measurements behind both decisions.
    assert_eq!(
        gpu.verified.outcome.best_speedup,
        fpga.verified.outcome.best_speedup
    );
}

#[test]
fn resuming_a_verified_artifact_under_a_power_policy_scores_without_remeasuring() {
    use fbo::coordinator::{PowerModel, PowerPolicy};

    let c = coordinator();
    let src = apps::fft_app_lib(64);
    let req = c.request(&src, "main");
    let saved = req
        .parse()
        .unwrap()
        .discover(&req)
        .unwrap()
        .reconcile(&req)
        .unwrap()
        .verify(&req)
        .unwrap()
        .to_json_string();

    // Default power policy: no power residue, the report serializes as v2
    // with no power section — byte-compatible with pre-power decisions.
    let perf = Verified::from_json_str(&saved).unwrap().arbitrate(&req).unwrap();
    assert!(perf.arbitration.power.is_none());
    let perf_json = fbo::coordinator::report_json::report_to_string(&perf.report());
    assert!(perf_json.contains("fbo-offload-report-v2"), "{perf_json}");
    assert!(!perf_json.contains("\"power\""));

    // perf-per-watt on the same saved measurements: the power stage scores
    // (no re-measurement — the artifact is all it reads) and the v3 report
    // records per-block energy.
    let ppw_req = c.request(&src, "main").with_power_policy(PowerPolicy::PerfPerWatt);
    let scored = Verified::from_json_str(&saved).unwrap().power_score(&ppw_req).unwrap();
    assert!(
        scored.scores.blocks.iter().any(|b| b.gpu.is_some()),
        "the measured fft pattern must be scored"
    );
    let powered = scored.arbitrate(&ppw_req).unwrap();
    let residue = powered.arbitration.power.as_ref().expect("power residue");
    assert!(residue.blocks.iter().any(|b| b.gpu_energy_j.is_some()));
    let powered_json = fbo::coordinator::report_json::report_to_string(&powered.report());
    assert!(powered_json.contains("fbo-offload-report-v3"), "{powered_json}");
    assert!(powered_json.contains("gpu_energy_j"));

    // An invalid caller-supplied wattage model fails in the PowerScore
    // stage, carrying the verified artifact.
    let mut bad_model = PowerModel::builtin();
    bad_model.gpu.active_watts = -1.0;
    let bad_req = c.request(&src, "main").with_power_model(bad_model);
    let err = Verified::from_json_str(&saved).unwrap().power_score(&bad_req).unwrap_err();
    assert_eq!(err.stage(), Stage::PowerScore);
    match err {
        OffloadError::PowerScoring { verified, .. } => {
            assert!(!verified.outcome.tried.is_empty(), "partial artifact must survive");
        }
        other => panic!("wrong variant: {other}"),
    }
}

// ----------------------------------------------------------- estimation

#[test]
fn conservative_pruning_measures_no_more_patterns_and_keeps_the_decision() {
    use fbo::coordinator::PrunePolicy;

    let c = coordinator();
    let src = apps::fft_app_lib(64);
    let full = c.offload(&src, "main").unwrap();

    let mut pruning = coordinator();
    pruning.prune_policy = PrunePolicy::Conservative(0.5);
    let pruned = pruning.offload(&src, "main").unwrap();

    assert!(
        pruned.outcome.tried.len() <= full.outcome.tried.len(),
        "pruning must never add measurements"
    );
    assert_eq!(pruned.outcome.best_enabled, full.outcome.best_enabled);
    assert_eq!(pruned.arbitration.backend, full.arbitration.backend);

    // A non-default estimator config leaves a residue: the v4 report
    // records the predictions next to what was measured...
    let est = pruned.arbitration.estimate.as_ref().expect("estimate residue");
    assert!(!est.blocks.is_empty());
    let json = fbo::coordinator::report_json::report_to_string(&pruned);
    assert!(json.contains("fbo-offload-report-v4"), "{json}");
    assert!(json.contains("predicted_secs"));

    // ...while the default path stays on the pre-estimate codec.
    let full_json = fbo::coordinator::report_json::report_to_string(&full);
    assert!(!full_json.contains("fbo-offload-report-v4"), "{full_json}");
    assert!(full.arbitration.estimate.is_none());
}

// ----------------------------------------------------------- observers

#[derive(Default)]
struct Recorder(Mutex<Vec<(Stage, Duration)>>);

impl StageObserver for Recorder {
    fn stage_completed(&self, stage: Stage, wall: Duration) {
        self.0.lock().unwrap().push((stage, wall));
    }
}

#[test]
fn observer_sees_every_stage_in_order() {
    let c = coordinator();
    let recorder = Arc::new(Recorder::default());
    let observer: Arc<dyn StageObserver> = recorder.clone();
    let req = c.request(&apps::matmul_app(64), "main").with_observer(observer);
    let report = req.run().unwrap();
    assert!(report.best_speedup() > 1.0);

    let stages: Vec<Stage> = recorder.0.lock().unwrap().iter().map(|(s, _)| *s).collect();
    assert_eq!(
        stages,
        vec![
            Stage::Parse,
            Stage::Discover,
            Stage::Reconcile,
            Stage::Estimate,
            Stage::Verify,
            Stage::PowerScore,
            Stage::Arbitrate
        ]
    );
}

// -------------------------------------------------------------- errors

#[test]
fn errors_carry_the_failing_stage_and_partial_artifact() {
    let c = coordinator();

    // Unparseable source: Parse stage.
    let err = c.request("int f( {", "main").run().unwrap_err();
    assert_eq!(err.stage(), Stage::Parse);

    // Missing entry point: caught up front, Parse stage.
    let err = c.request("int main() { return 0; }", "nope").run().unwrap_err();
    assert_eq!(err.stage(), Stage::Parse);
    assert!(err.message().contains("nope"), "{err}");

    // A diverging baseline is contained by fuel in the Verify stage, and
    // the error still carries the reconciled blocks of Steps 1-2.
    let mut c2 = coordinator();
    c2.verify.fuel = 100_000;
    let src = "
        void ludcmp(double a[], int n);
        int main() {
            double a[4];
            while (1) { a[0] = a[0] + 1.0; }
            ludcmp(a, 2);
            return 0;
        }";
    let err = c2.request(src, "main").run().unwrap_err();
    assert_eq!(err.stage(), Stage::Verify);
    match err {
        OffloadError::Verify { reconciled, .. } => {
            assert!(!reconciled.blocks.is_empty(), "partial artifact must survive");
        }
        other => panic!("wrong variant: {other}"),
    }
}

// ------------------------------------------------------------ placement

#[test]
fn place_stage_consumes_the_arbitrated_times() {
    let c = coordinator();
    let src = apps::fft_app_lib(64);
    let req = c.request(&src, "main");
    let arbitrated = req
        .parse()
        .unwrap()
        .discover(&req)
        .unwrap()
        .reconcile(&req)
        .unwrap()
        .verify(&req)
        .unwrap()
        .arbitrate(&req)
        .unwrap();

    let requirements = flow::Requirements {
        target_rps: 30.0,
        max_latency_ms: 20.0,
        budget_per_month: 10_000.0,
        max_kwh_per_month: None,
    };
    let locations = vec![flow::Location {
        name: "dc".into(),
        gpus: 16,
        fpgas: 8,
        cost_per_hour: 0.5,
        fpga_cost_per_hour: 0.2,
        energy_cost_per_kwh: 0.12,
        latency_ms: 10.0,
    }];
    let placed = arbitrated.place(&req, &requirements, &locations).unwrap();
    assert_eq!(placed.location, "dc");
    assert!(placed.instances >= 1);
    assert_ne!(placed.backend, Backend::Cpu, "fft offloads, so an accelerator hosts it");

    // Infeasible requirements surface as a structured Placement error
    // carrying the arbitrated artifact.
    let impossible = flow::Requirements {
        target_rps: 30.0,
        max_latency_ms: 1.0,
        budget_per_month: 10_000.0,
        max_kwh_per_month: None,
    };
    let err = arbitrated.place(&req, &impossible, &locations).unwrap_err();
    assert_eq!(err.stage(), Stage::Place);
    match err {
        OffloadError::Placement { arbitrated: partial, .. } => {
            assert_eq!(partial.arbitration.backend, arbitrated.arbitration.backend);
        }
        other => panic!("wrong variant: {other}"),
    }
}
