//! Integration tests for the offload service layer: decision-cache
//! content addressing, byte-identical replay, restart persistence, and
//! concurrent submission through the worker pool.

use std::path::PathBuf;
use std::time::Duration;

use fbo::coordinator::{apps, report_json, Backend, BackendPolicy, Stage};
use fbo::patterndb::PatternDb;
use fbo::service::{CacheKey, JobRejected, OffloadService, ServiceConfig, ShedReason};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Per-test config with an isolated cache dir under the temp root.
fn test_config(tag: &str) -> (ServiceConfig, PathBuf) {
    let dir = std::env::temp_dir().join(format!("fbo-servicetest-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = ServiceConfig::new(artifacts_dir());
    cfg.cache_dir = Some(dir.clone());
    cfg.workers = 2;
    cfg.verify.reps = 1;
    (cfg, dir)
}

// ------------------------------------------------------------ cache keys

#[test]
fn cache_key_survives_whitespace_and_comment_edits() {
    let db = PatternDb::builtin().fingerprint();
    let src = apps::lu_app_lib(64);
    // Comment-only and whitespace-only edits: the key hashes the parsed
    // and re-printed AST, not the raw bytes.
    let cosmetic = format!(
        "// regenerated 2026-07-31 by build bot\n{}\n\n/* trailing\n   notes */\n",
        src.replace("    ", "\t")
    );
    let a = CacheKey::compute(&src, "main", &db).unwrap();
    let b = CacheKey::compute(&cosmetic, "main", &db).unwrap();
    assert_eq!(a, b);

    // A semantic edit (different constant) must change the key.
    let edited = src.replace("int N = 64;", "int N = 32;");
    assert_ne!(a, CacheKey::compute(&edited, "main", &db).unwrap());
}

#[test]
fn pattern_db_change_invalidates_keys() {
    let base = PatternDb::builtin();
    let mut grown = base.clone();
    grown.external_library_list.push("tensor_contract".into());
    assert_ne!(base.fingerprint(), grown.fingerprint());

    let src = apps::lu_app_lib(64);
    let k_old = CacheKey::compute(&src, "main", &base.fingerprint()).unwrap();
    let k_new = CacheKey::compute(&src, "main", &grown.fingerprint()).unwrap();
    assert_eq!(k_old.source_hash, k_new.source_hash);
    assert_ne!(k_old, k_new, "DB growth must miss every old cache entry");
}

// ------------------------------------------------- byte-identical replay

#[test]
fn cached_decision_is_byte_identical_and_survives_restart() {
    let (cfg, dir) = test_config("replay");
    let src = apps::lu_app_lib(64);

    let (fresh_json, cached_json) = {
        let service = OffloadService::start(cfg.clone()).unwrap();
        let fresh = service.submit(&src, "main").wait().unwrap();
        assert!(!fresh.from_cache, "first submission must run the pipeline");
        assert!(fresh.report.best_speedup() > 1.0);

        // Same decision again — and through a cosmetic variant, which must
        // hash to the same content address.
        let cached = service.submit(&src, "main").wait().unwrap();
        assert!(cached.from_cache);
        let cosmetic = format!("{src}\n// deployed by ops\n");
        let via_variant = service.submit(&cosmetic, "main").wait().unwrap();
        assert!(via_variant.from_cache);
        assert_eq!(via_variant.report_json, fresh.report_json);

        let stats = service.stats();
        assert_eq!(stats.cache_misses, 1);
        assert_eq!(stats.cache_hits, 2);
        (fresh.report_json, cached.report_json)
    };
    assert_eq!(
        cached_json, fresh_json,
        "cached report must be byte-identical to the freshly computed one"
    );

    // Restart: the decision was persisted next to the artifacts dir
    // (redirected to a temp dir here) and must replay byte-identically.
    let service = OffloadService::start(cfg).unwrap();
    let replayed = service.submit(&src, "main").wait().unwrap();
    assert!(replayed.from_cache, "persisted decision must survive restart");
    assert_eq!(replayed.report_json, fresh_json);
    // The replayed report deserializes into a usable decision.
    assert_eq!(replayed.report.entry, "main");
    assert!(replayed.report.transformed_source.contains("__fb_lu_factor"));

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn real_report_round_trips_through_codec() {
    let (cfg, dir) = test_config("codec");
    let service = OffloadService::start(cfg).unwrap();
    let done = service.submit(&apps::matmul_app(64), "main").wait().unwrap();
    let reparsed = report_json::report_from_str(&done.report_json).unwrap();
    assert_eq!(report_json::report_to_string(&reparsed).as_str(), &*done.report_json);
    assert_eq!(reparsed.outcome.best_speedup, done.report.outcome.best_speedup);
    assert_eq!(reparsed.transformed_source, done.report.transformed_source);
    drop(service);
    std::fs::remove_dir_all(&dir).ok();
}

// ----------------------------------------------------------- concurrency

#[test]
fn concurrent_submissions_through_the_pool() {
    let (mut cfg, dir) = test_config("concurrent");
    cfg.workers = 3;
    let service = OffloadService::start(cfg).unwrap();

    // Three distinct applications, three copies each, all in flight at
    // once across three workers.
    let sources =
        [apps::lu_app_lib(64), apps::matmul_app(64), apps::fft_app_lib(64)];
    let jobs: Vec<(String, String)> = sources
        .iter()
        .cycle()
        .take(9)
        .map(|s| (s.clone(), "main".to_string()))
        .collect();
    let results = service.run_batch(&jobs);
    assert_eq!(results.len(), 9);

    let mut by_source: std::collections::HashMap<String, Vec<std::sync::Arc<str>>> =
        std::collections::HashMap::new();
    for (job, result) in jobs.iter().zip(results) {
        let done = result.expect("every job must complete");
        assert!(done.report.best_speedup() >= 1.0, "speedup {}", done.report.best_speedup());
        by_source.entry(job.0.clone()).or_default().push(done.report_json);
    }
    // Duplicates of the same source must agree byte-for-byte, whether they
    // were answered by the pipeline or the cache.
    for (_, reports) in by_source {
        assert_eq!(reports.len(), 3);
        assert_eq!(reports[0], reports[1]);
        assert_eq!(reports[0], reports[2]);
    }

    let stats = service.stats();
    assert_eq!(stats.submitted, 9);
    assert_eq!(stats.completed, 9);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.cache_hits + stats.cache_misses, 9);
    assert!(stats.cache_misses >= 3, "each distinct source verifies at least once");
    assert!(stats.latency_p50.is_some() && stats.latency_p95.is_some());

    service.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------- stage-granular cache

#[test]
fn verify_policy_change_replays_discovery_and_retarget_replays_verification() {
    let (cfg, dir) = test_config("stagecache");
    let src = apps::fft_app_lib(64);

    // Scratch run: full pipeline, stage artifacts persisted alongside the
    // decision.
    {
        let service = OffloadService::start(cfg.clone()).unwrap();
        let first = service.submit(&src, "main").wait().unwrap();
        assert!(!first.from_cache);
        assert_eq!(first.resumed_from, None, "nothing to resume from on a cold cache");
        let stats = service.stats();
        assert_eq!(stats.cache_misses, 1);
        assert_eq!(stats.reconciled_replays, 0);
        assert_eq!(stats.verified_replays, 0);
        // The observer-backed stage counters saw the whole pipeline run.
        for stage in
            ["parse", "discover", "reconcile", "estimate", "verify", "power-score", "arbitrate"]
        {
            let s = stats.stages.iter().find(|s| s.stage == stage).unwrap();
            assert_eq!(s.count, 1, "{stage} must have run exactly once");
        }
    }

    // A verify-settings change invalidates the decision and the verified
    // artifact but replays discovery from the cache: the hit/miss counters
    // show a full-decision miss alongside a reconciled-stage replay.
    {
        let mut reverify = cfg.clone();
        reverify.verify.reps = 2;
        let service = OffloadService::start(reverify).unwrap();
        let done = service.submit(&src, "main").wait().unwrap();
        assert!(!done.from_cache, "verify-settings change must re-verify");
        assert_eq!(done.resumed_from, Some(Stage::Reconcile), "discovery must replay");
        assert_eq!(done.report.outcome.baseline.reps, 2, "verification re-ran with new reps");
        let stats = service.stats();
        assert_eq!(stats.cache_hits, 0);
        assert_eq!(stats.cache_misses, 1);
        assert_eq!(stats.reconciled_replays, 1);
        assert_eq!(stats.verified_replays, 0);
        // Parse/discover/reconcile were replayed, not re-run.
        for stage in ["parse", "discover", "reconcile"] {
            let s = stats.stages.iter().find(|s| s.stage == stage).unwrap();
            assert_eq!(s.count, 0, "{stage} must have been replayed from cache");
        }
        assert_eq!(stats.stages.iter().find(|s| s.stage == "verify").unwrap().count, 1);
        // The analytic estimate is recomputed ahead of the re-measurement
        // (it is cheap and keyed upstream of the verify settings).
        assert_eq!(stats.stages.iter().find(|s| s.stage == "estimate").unwrap().count, 1);
    }

    // A backend retarget keeps the verified measurements and only
    // re-arbitrates. Under the default (`perf`) power configuration the
    // inert power scores are recomputed, not persisted, so the resume
    // point is the Verified tier (the power-tier resume is exercised by
    // `power_policy_change_replays_verification_and_perf_replays_v2_entries`).
    {
        let mut retarget = cfg.clone();
        retarget.backend_policy = BackendPolicy::Gpu;
        let service = OffloadService::start(retarget).unwrap();
        let done = service.submit(&src, "main").wait().unwrap();
        assert!(!done.from_cache, "--target change must re-arbitrate");
        assert_eq!(done.resumed_from, Some(Stage::Verify), "measurements must replay");
        assert_eq!(done.report.backend(), Backend::Gpu);
        let stats = service.stats();
        assert_eq!(stats.power_replays, 0);
        assert_eq!(stats.verified_replays, 1);
        assert_eq!(stats.reconciled_replays, 0);
        assert_eq!(stats.stages.iter().find(|s| s.stage == "verify").unwrap().count, 0);
        assert_eq!(
            stats.stages.iter().find(|s| s.stage == "estimate").unwrap().count,
            0,
            "a retarget resumes downstream of the estimate"
        );
        assert_eq!(stats.stages.iter().find(|s| s.stage == "power-score").unwrap().count, 1);
        assert_eq!(stats.stages.iter().find(|s| s.stage == "arbitrate").unwrap().count, 1);
    }

    // Unchanged config after all that: the original decision still
    // replays byte-identically from the full cache.
    {
        let service = OffloadService::start(cfg).unwrap();
        let done = service.submit(&src, "main").wait().unwrap();
        assert!(done.from_cache);
        assert_eq!(done.resumed_from, None);
    }

    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------- power-tier stage cache

#[test]
fn power_policy_change_replays_verification_and_perf_replays_v2_entries() {
    use fbo::coordinator::PowerPolicy;

    let (cfg, dir) = test_config("powercache");
    let src = apps::fft_app_lib(64);

    // Scratch run under the default (`perf`) power policy: the decision
    // persists as a v2 report with no power section — byte-for-byte what
    // a pre-power pipeline would have cached.
    let perf_json = {
        let service = OffloadService::start(cfg.clone()).unwrap();
        let first = service.submit(&src, "main").wait().unwrap();
        assert!(!first.from_cache);
        assert!(first.report_json.contains("fbo-offload-report-v2"));
        assert!(!first.report_json.contains("\"power\""));
        first.report_json
    };

    // Changing --power-policy resumes from the cached `Verified` artifact:
    // the measurements replay, power scoring + arbitration re-run, and no
    // verify stage executes (nothing is re-measured).
    {
        let mut ppw = cfg.clone();
        ppw.power_policy = PowerPolicy::PerfPerWatt;
        let service = OffloadService::start(ppw).unwrap();
        let done = service.submit(&src, "main").wait().unwrap();
        assert!(!done.from_cache, "--power-policy change must re-arbitrate");
        assert_eq!(done.resumed_from, Some(Stage::Verify), "measurements must replay");
        let stats = service.stats();
        assert_eq!(stats.verified_replays, 1);
        assert_eq!(stats.power_replays, 0);
        assert_eq!(
            stats.stages.iter().find(|s| s.stage == "verify").unwrap().count,
            0,
            "no re-measurement for a wattage question"
        );
        assert_eq!(stats.stages.iter().find(|s| s.stage == "power-score").unwrap().count, 1);
        // The non-default policy produces the v3 report with energies.
        assert!(done.report_json.contains("fbo-offload-report-v3"));
        assert!(done.report_json.contains("gpu_energy_j"));
        assert!(done.report.arbitration.power.is_some());
        // Same measured outcome behind both decisions.
        let perf_report = report_json::report_from_str(&perf_json).unwrap();
        assert_eq!(
            perf_report.outcome.best_speedup,
            done.report.outcome.best_speedup
        );
    }

    // A second perf-per-watt service start resumes deeper still: the
    // PowerScored artifact itself replays, so only arbitration runs.
    {
        let mut ppw = cfg.clone();
        ppw.power_policy = PowerPolicy::PerfPerWatt;
        ppw.backend_policy = BackendPolicy::Gpu;
        let service = OffloadService::start(ppw).unwrap();
        let done = service.submit(&src, "main").wait().unwrap();
        assert!(!done.from_cache);
        assert_eq!(done.resumed_from, Some(Stage::PowerScore));
        assert_eq!(service.stats().power_replays, 1);
    }

    // Back on the default policy, the original v2 entry replays
    // byte-identically: the default decision fingerprint is the pre-power
    // formula, so `perf` keeps serving decisions cached before (and
    // without) the power stage.
    {
        let service = OffloadService::start(cfg).unwrap();
        let done = service.submit(&src, "main").wait().unwrap();
        assert!(done.from_cache, "perf must replay the v2 entry");
        assert_eq!(done.report_json, perf_json, "byte-identical replay");
    }

    std::fs::remove_dir_all(&dir).ok();
}

// -------------------------------------------------------------- failures

#[test]
fn failures_are_contained() {
    let (cfg, dir) = test_config("failures");
    let service = OffloadService::start(cfg).unwrap();

    // Unparseable source fails the job (no cache key exists for it) —
    // and the error downcasts to the structured Parse-stage error, the
    // contract the service/mod.rs doc example routes on.
    let err = service.submit("int f( {", "main").wait().unwrap_err();
    let stage_err = err
        .downcast_ref::<fbo::coordinator::OffloadError>()
        .expect("parse failures must cross the service boundary as OffloadError");
    assert_eq!(stage_err.stage(), Stage::Parse);
    // Missing entry point fails the job but never poisons the pool.
    assert!(service.submit("int main() { return 0; }", "nope").wait().is_err());
    // The service keeps serving real work afterwards.
    let done = service.submit(&apps::lu_app_lib(64), "main").wait().unwrap();
    assert!(done.report.best_speedup() > 1.0);

    let stats = service.stats();
    assert_eq!(stats.failed, 2);
    assert_eq!(stats.completed, 1);
    // Failed decisions are never cached. The one successful pipeline run
    // writes three entries: the full decision plus the Reconciled and
    // Verified stage artifacts it can later resume from (the inert
    // default power scores are recomputed, never persisted).
    assert_eq!(stats.cache_entries, 3);

    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------- admission control

/// Distinct cache keys over the same prebuilt kernels: appending an
/// unused function churns the AST hash without needing new artifacts.
fn churned_sources(base: &str, n: usize) -> Vec<String> {
    (0..n).map(|i| format!("{base}\nint churn_{i}() {{ return {i}; }}\n")).collect()
}

#[test]
fn queue_limit_sheds_with_structured_rejection() {
    let (mut cfg, dir) = test_config("queuefull");
    cfg.workers = 1;
    cfg.admission.queue_limit = 1;
    let service = OffloadService::start(cfg).unwrap();

    // Six distinct sources into one worker with a one-slot queue: one job
    // runs, one waits, and the burst's tail must shed immediately with
    // the structured rejection (submits are microseconds; a pipeline run
    // is not, so the queue cannot drain between them).
    let sources = churned_sources(&apps::matmul_app(64), 6);
    let handles: Vec<_> = sources.iter().map(|s| service.submit(s, "main")).collect();

    let mut completed = 0u64;
    let mut shed = 0u64;
    for h in handles {
        match h.wait() {
            Ok(done) => {
                assert!(!done.from_cache, "distinct sources never replay");
                completed += 1;
            }
            Err(e) => {
                let r = e.downcast_ref::<JobRejected>().expect("sheds must carry JobRejected");
                assert_eq!(r.reason, ShedReason::QueueFull);
                assert!(r.queue_depth >= 1, "shed must report the observed depth");
                assert!(r.retry_after > Duration::ZERO, "QueueFull must hint a backoff");
                shed += 1;
            }
        }
    }
    assert!(shed >= 1, "a one-slot queue must shed under a burst of 6");
    assert_eq!(completed + shed, 6);

    // Shed is its own outcome — never counted as a failure.
    let stats = service.stats();
    assert_eq!(stats.submitted, 6);
    assert_eq!(stats.completed, completed);
    assert_eq!(stats.jobs_shed, shed);
    assert_eq!(stats.failed, 0);

    service.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn rate_limit_is_per_client_and_covers_cache_hits() {
    let (mut cfg, dir) = test_config("ratelimit");
    cfg.admission.rate_per_client = Some(0.001);
    cfg.admission.burst = 1.0;
    let service = OffloadService::start(cfg).unwrap();
    let src = apps::lu_app_lib(64);

    let first = service.submit_as(&src, "main", "alice").wait().unwrap();
    assert!(!first.from_cache);

    // alice spent her only token and accrual is ~17 min/token, so her
    // next submit sheds deterministically — even though the decision is
    // now cached (rate limiting admits *requests*, not pipeline work, so
    // it applies before the cache probe).
    let err = service.submit_as(&src, "main", "alice").wait().unwrap_err();
    let r = err.downcast_ref::<JobRejected>().expect("rate sheds must carry JobRejected");
    assert_eq!(r.reason, ShedReason::RateLimited);
    assert!(r.retry_after > Duration::from_secs(60), "accrual at 0.001/s is slow");

    // The bucket is per client: bob replays the cached decision at once,
    // byte-identically.
    let bob = service.submit_as(&src, "main", "bob").wait().unwrap();
    assert!(bob.from_cache);
    assert_eq!(bob.report_json, first.report_json);

    let stats = service.stats();
    assert_eq!(stats.submitted, 3);
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.jobs_shed, 1);
    assert_eq!(stats.failed, 0);

    service.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn graceful_shutdown_drains_queued_work_and_sheds_new_submits() {
    let (mut cfg, dir) = test_config("shutdown");
    cfg.workers = 2;
    // The measure fan-out races the drain: sub-measurements dispatched to
    // a sibling that already stopped must fall back locally, not deadlock.
    cfg.verify_parallel = 2;
    let service = OffloadService::start(cfg).unwrap();

    let base = apps::matmul_app(64);
    let sources = churned_sources(&base, 4);
    let handles: Vec<_> = sources.iter().map(|s| service.submit(s, "main")).collect();

    // Drain-then-stop: every job admitted above was enqueued ahead of the
    // shutdown markers and must complete, in flight or still queued.
    service.begin_shutdown();
    for h in handles {
        let done = h.wait().expect("jobs admitted before shutdown must drain");
        assert!(done.report.best_speedup() >= 1.0);
    }

    // New work is refused with the structured rejection and a zero retry
    // hint (a draining service never becomes admittable again).
    let err = service.submit(&base, "main").wait().unwrap_err();
    let r = err.downcast_ref::<JobRejected>().expect("post-drain submits must shed");
    assert_eq!(r.reason, ShedReason::ShuttingDown);
    assert_eq!(r.retry_after, Duration::ZERO);

    let stats = service.stats();
    assert_eq!(stats.completed, 4);
    assert_eq!(stats.jobs_shed, 1);
    assert_eq!(stats.failed, 0);

    // begin_shutdown is idempotent, and the full join cannot deadlock on
    // the already-drained queues.
    service.begin_shutdown();
    service.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn worker_table_accounts_for_measure_fan_out() {
    let (mut cfg, dir) = test_config("workertable");
    cfg.workers = 2;
    cfg.verify_parallel = 2;
    let service = OffloadService::start(cfg).unwrap();

    // One decision job at a time: the verifying worker fans measurement
    // sub-jobs to its idle sibling, which absorbs them at the top of its
    // queue loop — the deterministic fan-out path.
    let done = service.submit(&apps::sensor_fusion_app(64), "main").wait().unwrap();
    assert!(!done.from_cache);

    let stats = service.stats();
    // The ledger invariant: every submit resolves as exactly one of
    // completed / failed / shed.
    assert_eq!(stats.submitted, stats.completed + stats.failed + stats.jobs_shed);
    // The worker table's decision column sums to the jobs the pool ran;
    // fanned measurement sub-jobs live in their own column, never
    // inflating the decision count the ledger audits against.
    let decisions: u64 = stats.workers.iter().map(|w| w.jobs).sum();
    assert_eq!(decisions, stats.completed + stats.failed);
    let absorbed: u64 = stats.workers.iter().map(|w| w.measure_jobs).sum();
    assert!(absorbed > 0, "the idle sibling must absorb fanned sub-jobs: {}", stats.render_full());
    assert_eq!(
        absorbed, stats.patterns_parallel,
        "every fanned pattern lands in exactly one sibling's measure column"
    );
    let full = stats.render_full();
    assert!(full.contains("measure sub-jobs"), "{full}");

    service.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_artifacts_fail_at_startup() {
    let mut cfg = ServiceConfig::new("/nonexistent/fbo-artifacts");
    cfg.persist = false;
    let err = match OffloadService::start(cfg) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("startup must fail without artifacts"),
    };
    assert!(err.contains("make artifacts"), "{err}");
}
