//! Bench: regenerate **Fig. 5** — the headline table: speedup vs all-CPU
//! for loop offloading [33] vs function-block offloading (this paper), on
//! the Fourier-transform and matrix-calculation applications.
//!
//! Paper values (2048, Quadro P4000):
//!   Fourier transform:  5.4x (loops)  ->    730x (function blocks)
//!   Matrix calculation:  38x (loops)  -> 130000x (function blocks)
//!
//! We do not chase the absolute numbers (our CPU substrate is an AST
//! interpreter, not gcc on a Core i5) — the *shape* is the claim: function
//! blocks beat loop offloading by orders of magnitude and the matrix gap
//! is the larger one. `FBO_N` (default 64; 256 = headline run).
//!
//! Run: `cargo bench --bench fig5_speedups`

use std::time::Instant;

use fbo::coordinator::{apps, loop_offload, Coordinator};
use fbo::ga::GaConfig;
use fbo::interp::{Interp, Slice, Value};
use fbo::metrics::{fmt_duration, fmt_speedup, Table};
use fbo::parser;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test");
    let n = env_usize("FBO_N", 64);
    let artifacts =
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut coordinator = Coordinator::open(&artifacts)?;
    coordinator.verify.reps = if smoke || n >= 256 { 1 } else { 3 };

    println!("== Fig. 5: speedup vs all-CPU (n={n}) ==");
    let cases = [
        ("Fourier transform", apps::fft_app_lib(n), (5.4, 730.0)),
        ("Matrix calculation", apps::lu_app_lib(n), (38.0, 130_000.0)),
    ];

    let mut t = Table::new(&[
        "application",
        "all-CPU time",
        "loop offload [33]",
        "function blocks",
        "paper (loops -> blocks)",
    ]);

    let mut shape = Vec::new();
    for (label, src, paper) in &cases {
        eprintln!("-- {label} --");
        let report = coordinator.offload(src, "main")?;
        let prog = parser::parse(src)?;
        let linked = coordinator.link_cpu_libraries(&prog)?;
        let ga_cfg = GaConfig {
            population: 10,
            generations: if n >= 256 { 5 } else { 8 },
            ..Default::default()
        };
        let ga = loop_offload::ga_loop_search(&linked, "main", &ga_cfg, 1, u64::MAX)?;
        t.row(&[
            label.to_string(),
            fmt_duration(report.outcome.baseline.median),
            format!("{}x", fmt_speedup(ga.ga.best_speedup())),
            format!("{}x", fmt_speedup(report.best_speedup())),
            format!("{}x -> {}x", paper.0, paper.1),
        ]);
        shape.push((label, ga.ga.best_speedup(), report.best_speedup()));
    }
    print!("{}", t.render());

    // Shape gates.
    for (label, loops, blocks) in &shape {
        assert!(
            blocks > loops,
            "{label}: function blocks ({blocks:.1}x) must beat loop offload ({loops:.1}x)"
        );
    }
    let fft_gap = shape[0].2 / shape[0].1.max(1.0);
    let lu_gap = shape[1].2 / shape[1].1.max(1.0);
    println!(
        "\nshape: FFT block/loop gap {fft_gap:.1}x, matrix gap {lu_gap:.1}x \
         (paper: 135x and 3421x — matrix gap larger)"
    );

    // ---- block-level measurement (the paper's granularity) ----------
    // §5.1.2 measures the *processing time of the transform itself*
    // (cuFFT vs the NR code), not the surrounding data generation. Here:
    // CPU = interpreting the linked NR routine on prepared data, GPU =
    // executing the PJRT artifact on the same data.
    println!("\n== block processing time (paper's measurement granularity) ==");
    let mut t2 = Table::new(&["block", "CPU (NR interp)", "accel artifact", "speedup", "paper"]);

    // FFT block.
    {
        // A call site is needed for the analyzer to treat fft2d as an
        // external library (linking is call-driven).
        let lib_src = "void fft2d(double re[], double im[], int n);
                       void use_it(double re[], double im[], int n) { fft2d(re, im, n); }";
        let prog = parser::parse(lib_src)?;
        let linked = coordinator.link_cpu_libraries(&prog)?;
        let mut interp = Interp::new(&linked)?;
        let re = Slice::zeros(&[n * n], false);
        let im = Slice::zeros(&[n * n], false);
        for i in 0..n * n {
            re.set(i, (0.02 * i as f64).sin()).unwrap();
        }
        let t0 = Instant::now();
        interp.run(
            "fft2d",
            &[Value::Arr(re.clone()), Value::Arr(im.clone()), Value::Int(n as i64)],
        )?;
        let cpu = t0.elapsed();

        let art = format!("fft2d_n{n}");
        coordinator.engine.artifact(&art)?; // compile outside timing
        let re32 = re.to_vec_f32();
        let im32 = im.to_vec_f32();
        let mut best = f64::MAX;
        for _ in 0..5 {
            let t0 = Instant::now();
            coordinator.engine.execute(&art, &[re32.clone(), im32.clone()])?;
            best = best.min(t0.elapsed().as_secs_f64());
        }
        let speed = cpu.as_secs_f64() / best;
        t2.row(&[
            "Fourier transform".into(),
            fmt_duration(cpu),
            format!("{:.2}ms", best * 1e3),
            format!("{}x", fmt_speedup(speed)),
            "730x".into(),
        ]);
    }

    // LU block.
    {
        let lib_src = "void ludcmp(double a[], int n);
                       void use_it(double a[], int n) { ludcmp(a, n); }";
        let prog = parser::parse(lib_src)?;
        let linked = coordinator.link_cpu_libraries(&prog)?;
        let mut interp = Interp::new(&linked)?;
        let a = Slice::zeros(&[n * n], false);
        for i in 0..n {
            for j in 0..n {
                a.set(i * n + j, if i == j { n as f64 } else { 0.2 }).unwrap();
            }
        }
        let a_cpu = Slice::new(a.to_vec(), vec![n * n], false);
        let t0 = Instant::now();
        interp.run("ludcmp", &[Value::Arr(a_cpu), Value::Int(n as i64)])?;
        let cpu = t0.elapsed();

        let art = format!("lu_factor_n{n}");
        coordinator.engine.artifact(&art)?;
        let a32 = a.to_vec_f32();
        let mut best = f64::MAX;
        for _ in 0..5 {
            let t0 = Instant::now();
            coordinator.engine.execute(&art, &[a32.clone()])?;
            best = best.min(t0.elapsed().as_secs_f64());
        }
        let speed = cpu.as_secs_f64() / best;
        t2.row(&[
            "Matrix calculation".into(),
            fmt_duration(cpu),
            format!("{:.2}ms", best * 1e3),
            format!("{}x", fmt_speedup(speed)),
            "130000x".into(),
        ]);
    }
    print!("{}", t2.render());
    Ok(())
}
