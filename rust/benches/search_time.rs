//! Bench: search-time comparison (paper §5.2 text) — the GA loop search
//! "took several hours or more", while function-block offloading
//! "completed in a few minutes".
//!
//! Both searches are dominated by measured verification trials, so the fair
//! comparison is (a) wall-clock of each search end-to-end and (b) the
//! number of verification runs each needs. Function-block search needs
//! k + 1 (+1 combined) runs for k blocks; the GA needs population ×
//! generations (minus cache hits).
//!
//! Run: `cargo bench --bench search_time`

use std::time::Instant;

use fbo::coordinator::{apps, loop_offload, Coordinator};
use fbo::ga::GaConfig;
use fbo::metrics::{fmt_duration, Table};
use fbo::parser;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n = env_usize("FBO_N", 64);
    let artifacts =
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut coordinator = Coordinator::open(&artifacts)?;
    coordinator.verify.reps = 1;
    // Warm every artifact first: XLA compilation is the cuFFT/cuSOLVER
    // "library install", not part of the search.
    for name in coordinator.engine.artifact_names() {
        let _ = coordinator.engine.artifact(&name);
    }

    println!("== search time: function-block vs GA loop search (n={n}) ==");
    // The paper's per-trial cost is dominated by the compiler invocation
    // (~1 min PGI compile per pattern); our interpreter trials skip that,
    // so the scale-free comparison is the NUMBER of verification trials,
    // projected back at the paper's per-trial cost.
    const PAPER_TRIAL_SECS: f64 = 60.0;
    let mut t = Table::new(&[
        "application",
        "FB search wall",
        "FB trials",
        "GA search wall",
        "GA trials",
        "projected FB",
        "projected GA",
    ]);
    let mut checks = Vec::new();

    for (label, src) in [
        ("Fourier transform", apps::fft_app_lib(n)),
        ("Matrix calculation", apps::lu_app_lib(n)),
    ] {
        // Function-block search (Steps 1-3 wall-clock).
        let t0 = Instant::now();
        let report = coordinator.offload(&src, "main")?;
        let fb_wall = t0.elapsed();
        let fb_trials = report.outcome.tried.len() + 1; // + baseline

        // GA loop search at the paper's scale (pop 12 x 10 generations).
        let prog = parser::parse(&src)?;
        let linked = coordinator.link_cpu_libraries(&prog)?;
        let cfg = GaConfig { population: 12, generations: 10, ..Default::default() };
        let t0 = Instant::now();
        let ga = loop_offload::ga_loop_search(&linked, "main", &cfg, 1, u64::MAX)?;
        let ga_wall = t0.elapsed();

        t.row(&[
            label.to_string(),
            fmt_duration(fb_wall),
            fb_trials.to_string(),
            fmt_duration(ga_wall),
            ga.ga.trials.to_string(),
            format!("{:.0} min", fb_trials as f64 * PAPER_TRIAL_SECS / 60.0),
            format!("{:.0} min", ga.ga.trials as f64 * PAPER_TRIAL_SECS / 60.0),
        ]);
        checks.push((label.to_string(), fb_trials, ga.ga.trials));
    }
    print!("{}", t.render());
    println!(
        "\npaper: GA = hours+ (pop x generations compile+measure trials), function\n\
         blocks = minutes (k blocks -> k+1 trials). The trial counts above, projected\n\
         at the paper's ~1 min/trial, reproduce that gap; our absolute walls differ\n\
         because interpreter trials skip the per-pattern compiler invocation."
    );
    for (label, fb_trials, ga_trials) in checks {
        assert!(ga_trials > fb_trials, "{label}: GA needs more measured trials");
    }
    Ok(())
}
