//! Bench: regenerate **Fig. 4** — GA generations vs best speedup for the
//! Fourier-transform app under loop offloading (prior work [33]).
//!
//! Paper series: best-of-generation climbs past 5x vs all-CPU on the 2048
//! FFT app. We print the same series measured on our verification
//! environment. Set `FBO_N` (default 64) and `FBO_GENS` (default 10).
//!
//! Run: `cargo bench --bench fig4_ga_generations`

use fbo::coordinator::{apps, loop_offload, Coordinator};
use fbo::ga::GaConfig;
use fbo::metrics::Table;
use fbo::parser;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test");
    let n = env_usize("FBO_N", 64);
    let gens = env_usize("FBO_GENS", if smoke { 4 } else { 10 });
    let artifacts =
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let coordinator = Coordinator::open(&artifacts)?;

    println!("== Fig. 4: GA loop-offload search, FFT app (n={n}, {gens} generations) ==");
    let prog = parser::parse(&apps::fft_app_lib(n))?;
    let linked = coordinator.link_cpu_libraries(&prog)?;
    let cfg = GaConfig { population: 12, generations: gens, ..Default::default() };
    let r = loop_offload::ga_loop_search(&linked, "main", &cfg, 1, u64::MAX)?;

    let mut t = Table::new(&["generation", "best speedup", "mean speedup", "trials"]);
    for g in &r.ga.history {
        t.row(&[
            g.generation.to_string(),
            format!("{:.2}", g.best_speedup),
            format!("{:.2}", g.mean_speedup),
            g.trials.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "final best: {:.2}x ({} parallelizable-loop genes, {} measured trials)",
        r.ga.best_speedup(),
        r.loop_ids.len(),
        r.ga.trials
    );
    println!("paper reference: >5x by the final generations on the 2048 app.");
    println!(
        "NOTE: on NR-structured code our loop baseline under-credits [33] — its\n\
         data-transfer-reduction optimization is not modeled (DESIGN.md), so the\n\
         FFT app tops out low. The mechanism itself is shown on a loop-friendly\n\
         stencil workload below."
    );

    // Shape assertions (the bench doubles as a regression gate).
    assert!(!r.ga.history.is_empty());
    let first = r.ga.history.first().unwrap().best_speedup;
    let last = r.ga.history.last().unwrap().best_speedup;
    assert!(last >= first, "GA best must be monotone");
    assert!(last >= 1.0, "GA must never end below the all-CPU baseline");

    // Part 2: the same GA on a loop-offload-friendly stencil app — mixed
    // genes (3 big wins, 4 launch-bound losers) give the classic rising
    // curve of Fig. 4.
    println!("\n== Fig. 4 (mechanism): GA on the stencil app (n={n}) ==");
    let prog2 = parser::parse(&apps::stencil_app(n.max(96)))?;
    let cfg2 = GaConfig {
        population: 10,
        generations: gens,
        mutation_rate: 0.08,
        ..Default::default()
    };
    let r2 = loop_offload::ga_loop_search(&prog2, "main", &cfg2, 1, u64::MAX)?;
    let mut t2 = Table::new(&["generation", "best speedup", "mean speedup", "trials"]);
    for g in &r2.ga.history {
        t2.row(&[
            g.generation.to_string(),
            format!("{:.2}", g.best_speedup),
            format!("{:.2}", g.mean_speedup),
            g.trials.to_string(),
        ]);
    }
    print!("{}", t2.render());
    println!(
        "final best: {:.2}x with gene {:?} ({} genes)",
        r2.ga.best_speedup(),
        r2.ga.best_gene,
        r2.loop_ids.len()
    );
    assert!(
        r2.ga.best_speedup() > 3.0,
        "stencil loop offload must exceed 3x, got {:.2}",
        r2.ga.best_speedup()
    );
    Ok(())
}
