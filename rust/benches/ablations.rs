//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **winner combination** (paper §4.2: measure blocks individually,
//!    then combine the winners and re-measure) vs individual-only — on a
//!    two-block app (factor + solve).
//! 2. **similarity threshold sweep** — precision/recall over a seeded
//!    corpus of true copies and independent look-alikes (paper §3.4 B-2:
//!    threshold chooses the operating point; independent code is out of
//!    scope).
//! 3. **FPGA candidate narrowing** (paper §3.2: intensity-rank + resource
//!    pre-check before the multi-hour compiles) vs exhaustive compilation —
//!    in simulated toolchain-hours on the virtual clock.
//!
//! Run: `cargo bench --bench ablations`

use fbo::analysis;
use fbo::coordinator::{Coordinator, VerifyConfig};
use fbo::fpga;
use fbo::metrics::{fmt_speedup, Table};
use fbo::parser;
use fbo::patterndb::{corpus, PatternDb};
use fbo::similarity::{self, CharVector};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// App with two independent offloadable blocks: LU factor + solve.
fn two_block_app(n: usize) -> String {
    format!(
        r#"
int N = {n};
void ludcmp(double a[], int n);
void lubksb(double a[], int n, double b[], int nrhs);
int main() {{
    double a[N * N];
    double b[N * 8];
    int i, j;
    for (i = 0; i < N; i++)
        for (j = 0; j < N; j++)
            a[i * N + j] = 0.2 * sin(0.01 * (i * j + 1));
    for (i = 0; i < N; i++) a[i * N + i] = a[i * N + i] + N;
    for (i = 0; i < N * 8; i++) b[i] = 1.0 + i % 5;
    ludcmp(a, N);
    lubksb(a, N, b, 8);
    double s = 0.0;
    for (i = 0; i < N * 8; i++) s += b[i];
    printf("sum %g\n", s);
    return s;
}}
"#
    )
}

fn ablation_combination() -> anyhow::Result<()> {
    println!("== ablation 1: winner combination (paper's two-phase search) ==");
    let mut c = Coordinator::open(&artifacts_dir())?;
    c.verify = VerifyConfig { reps: 3, ..Default::default() };
    let n = 64;
    // NOTE: lubksb re-factors inside the artifact (lu_solve = getrf+getrs
    // fused), so the combined pattern must still win over each individual.
    let report = c.offload(&two_block_app(n), "main")?;
    let mut t = Table::new(&["pattern", "speedup", "correct"]);
    for p in &report.outcome.tried {
        t.row(&[p.label.clone(), format!("{}x", fmt_speedup(p.speedup)), p.output_ok.to_string()]);
    }
    print!("{}", t.render());
    let combined = report
        .outcome
        .tried
        .iter()
        .find(|p| p.label == "combined-winners");
    match combined {
        Some(p) => {
            let best_individual = report
                .outcome
                .tried
                .iter()
                .filter(|q| q.label != "combined-winners")
                .map(|q| q.speedup)
                .fold(0.0f64, f64::max);
            println!(
                "combined {}x vs best individual {}x -> combination {}",
                fmt_speedup(p.speedup),
                fmt_speedup(best_individual),
                if p.speedup > best_individual { "WINS (kept)" } else { "loses (discarded)" }
            );
        }
        None => println!("(fewer than two individual winners; combination phase skipped)"),
    }
    Ok(())
}

fn ablation_threshold() -> anyhow::Result<()> {
    println!("\n== ablation 2: similarity threshold sweep ==");
    let db = PatternDb::builtin();

    // Seeded corpus: true copies (renamed/edited NR code) and independent
    // numeric functions that merely look similar.
    let true_copies = [
        corpus::NR_LUDCMP.replace("ludcmp_nopiv", "my_lu").replace("factor", "f0"),
        corpus::NR_MATMUL.replace("matmul_cpu", "mm_fast").replace("sum", "acc"),
        corpus::NR_LUDCMP_2D.replace("ludcmp_grid", "grid_fact").replace("pivot", "pp"),
    ];
    let independents = [
        // Jacobi sweep: loopy numeric code, but not a copy of anything.
        "void jacobi(double x[], double b[], double a[], int n) {
            int i, j, it;
            double s;
            for (it = 0; it < 10; it++) {
                for (i = 0; i < n; i++) {
                    s = b[i];
                    for (j = 0; j < n; j++) {
                        if (j != i) s -= a[i * n + j] * x[j];
                    }
                    x[i] = s / a[i * n + i];
                }
            }
        }"
        .to_string(),
        // Histogram: different shape entirely.
        "void hist(double v[], int n, double h[], int bins) {
            int i; int b;
            for (i = 0; i < n; i++) {
                b = (int) (v[i] * bins);
                if (b >= 0) { if (b < bins) { h[b] += 1.0; } }
            }
        }"
        .to_string(),
        // Dot product chain.
        "double chain(double a[], double b[], double c[], int n) {
            int i; double s1 = 0.0; double s2 = 0.0;
            for (i = 0; i < n; i++) s1 += a[i] * b[i];
            for (i = 0; i < n; i++) s2 += b[i] * c[i];
            return s1 * s2;
        }"
        .to_string(),
    ];

    let mut t = Table::new(&["threshold", "recall (copies)", "false pos (independent)"]);
    for threshold in [0.70, 0.80, 0.85, 0.90, 0.95] {
        let det = similarity::Detector::new(&db, threshold)?;
        let mut hit = 0;
        for src in &true_copies {
            let prog = parser::parse(src)?;
            if !det.detect(&prog).is_empty() {
                hit += 1;
            }
        }
        let mut fp = 0;
        for src in &independents {
            let prog = parser::parse(src)?;
            if !det.detect(&prog).is_empty() {
                fp += 1;
            }
        }
        t.row(&[
            format!("{threshold:.2}"),
            format!("{hit}/{}", true_copies.len()),
            format!("{fp}/{}", independents.len()),
        ]);
        if (threshold - similarity::DEFAULT_THRESHOLD).abs() < 1e-9 {
            assert_eq!(hit, true_copies.len(), "default threshold must catch all copies");
        }
    }
    print!("{}", t.render());
    println!(
        "(paper: copies are in scope, independently-written code is out; count-vector
         similarity CAN false-positive on look-alike kernels — Jacobi scores ~0.94 vs
         the GEMM record. The measured verification phase is the safety net:)"
    );

    // Demonstrate the safety net end-to-end: a Jacobi app gets (wrongly)
    // matched, the bogus replacement produces wrong output, and the
    // verification environment rejects the pattern.
    let mut c = Coordinator::open(&artifacts_dir())?;
    c.verify = VerifyConfig { reps: 1, ..Default::default() };
    let jacobi_app = format!(
        "{}\nint main() {{\n    double x[64]; double b[64]; double a[64 * 64];\n    int i;\n    for (i = 0; i < 64; i++) {{ x[i] = 0.0; b[i] = 1.0; }}\n    for (i = 0; i < 64 * 64; i++) a[i] = 0.01;\n    for (i = 0; i < 64; i++) a[i * 64 + i] = 64.0;\n    jacobi(x, b, a, 64);\n    double s = 0.0;\n    for (i = 0; i < 64; i++) s += x[i];\n    return s;\n}}",
        independents[0]
    );
    let report = c.offload(&jacobi_app, "main")?;
    let any_false_match = report.blocks.iter().any(|b| {
        matches!(&b.via, fbo::coordinator::DiscoveryPath::Similarity { .. })
    });
    let verified_win = report
        .outcome
        .tried
        .iter()
        .any(|p| p.speedup > 1.0 && p.output_ok && report.outcome.best_enabled.iter().any(|&e| e));
    println!(
        "jacobi app: similarity false-match = {any_false_match}; verification kept a wrong          pattern = {}",
        verified_win && any_false_match
    );
    if any_false_match {
        assert!(
            report.outcome.tried.iter().all(|p| p.output_ok || p.speedup == 0.0 || !p.output_ok),
            "bookkeeping"
        );
        // The wrongly-matched pattern must NOT be selected as the winner.
        let selected_wrong = report
            .outcome
            .tried
            .iter()
            .any(|p| !p.output_ok && p.enabled == report.outcome.best_enabled && p.speedup > 1.0);
        assert!(!selected_wrong, "verification must reject incorrect patterns");
    }
    Ok(())
}

fn ablation_fpga_narrowing() -> anyhow::Result<()> {
    println!("\n== ablation 3: FPGA candidate narrowing vs exhaustive compiles ==");
    // Loop candidates from the (linked) LU app: rank by arithmetic
    // intensity, then compile top-k on the simulated 3h-per-compile chain.
    let c = Coordinator::open(&artifacts_dir())?;
    let prog = parser::parse(&fbo::coordinator::apps::lu_app_lib(64))?;
    let linked = c.link_cpu_libraries(&prog)?;
    let a = analysis::analyze(&linked);

    let mut specs = Vec::new();
    let mut intensity = Vec::new();
    for (i, l) in a.loops.iter().enumerate() {
        // Reconstruct the loop stmt for intensity from the inventory data.
        let trips = l.nest_trip_count.unwrap_or(1000);
        let flops = (l.body_stmts as u64).max(1) * 2;
        let report = fbo::analysis::IntensityReport {
            flops_per_iter: flops,
            mem_per_iter: (l.body_stmts as u64).max(1),
            trips: Some(trips),
            ratio: 2.0,
            score: 2.0 * trips as f64,
        };
        specs.push(fbo::fpga::KernelSpec {
            name: format!("loop{i}@{}", l.span),
            resources: fpga::estimate_loop_resources(&report, 4),
            trips,
            ii: 1,
            transfer_bytes: 64 * 64 * 8,
        });
        intensity.push(report.score);
    }

    // Narrowed: top-2 by intensity with pre-check.
    let narrowed = fpga::HlsCompiler::new(fpga::ARRIA10_GX);
    let picked = fpga::narrow_and_compile(&narrowed, &specs, &intensity, 2);
    // Exhaustive: compile everything.
    let exhaustive = fpga::HlsCompiler::new(fpga::ARRIA10_GX);
    let mut all = Vec::new();
    for s in &specs {
        if let Ok(k) = exhaustive.compile(s) {
            all.push(k);
        }
    }

    let mut t =
        Table::new(&["strategy", "compiles", "simulated toolchain-hours", "best exec (model)"]);
    t.row(&[
        "narrowed (paper)".into(),
        picked.len().to_string(),
        format!("{:.1}", narrowed.clock.elapsed_hours()),
        picked
            .first()
            .map(|k| format!("{:.2}ms", k.exec_secs() * 1e3))
            .unwrap_or_else(|| "-".into()),
    ]);
    t.row(&[
        "exhaustive".into(),
        all.len().to_string(),
        format!("{:.1}", exhaustive.clock.elapsed_hours()),
        all.iter()
            .map(|k| k.exec_secs())
            .min_by(|a, b| a.partial_cmp(b).unwrap())
            .map(|s| format!("{:.2}ms", s * 1e3))
            .unwrap_or_else(|| "-".into()),
    ]);
    print!("{}", t.render());
    assert!(
        narrowed.clock.elapsed_hours() < exhaustive.clock.elapsed_hours(),
        "narrowing must save simulated toolchain time"
    );
    println!("(paper: compiles take ~3h each, so candidates are narrowed before compiling)");
    Ok(())
}

/// Bonus sanity sweep: characteristic vectors are rename-invariant.
fn ablation_vector_invariance() -> anyhow::Result<()> {
    println!("\n== ablation 4: characteristic-vector rename invariance ==");
    let orig = CharVector::from_source_merged(corpus::NR_MATMUL)?;
    let renamed = CharVector::from_source_merged(
        &corpus::NR_MATMUL.replace("matmul_cpu", "zzz").replace("sum", "q"),
    )?;
    let sim = similarity::similarity(&orig, &renamed);
    println!("similarity(original, renamed) = {sim:.4}");
    assert!(sim > 0.999, "pure renames must not move the vector");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    ablation_combination()?;
    ablation_threshold()?;
    ablation_fpga_narrowing()?;
    ablation_vector_invariance()?;
    Ok(())
}
