//! Bench gate: telemetry passivity and trace validity.
//!
//! Fresh pipeline runs are wall-clock-measured and therefore never
//! byte-identical to each other, so the passivity invariant is gated the
//! way the cache makes it real: an **untraced** service verifies the
//! sensor-fusion app, then a **traced** service on the same cache dir
//! must replay that decision byte-for-byte (telemetry shifts no
//! fingerprint). A separately traced fresh run produces the full span
//! trace, whose JSONL sink must round-trip line-by-line and whose Chrome
//! export must parse with one `"X"` span per pipeline stage.
//!
//! Run: `cargo bench --bench telemetry_trace` (`-- --test` for the CI
//! smoke pass). Records: `BENCH_telemetry.json` at the repo root.

use std::path::{Path, PathBuf};
use std::time::Instant;

use fbo::coordinator::apps;
use fbo::metrics::fmt_duration;
use fbo::patterndb::json::{self, Json};
use fbo::service::{OffloadService, ServiceConfig};
use fbo::telemetry::TraceRecord;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn config(artifacts: &Path, cache_dir: &Path) -> ServiceConfig {
    let mut cfg = ServiceConfig::new(artifacts);
    cfg.cache_dir = Some(cache_dir.to_path_buf());
    cfg.workers = 1;
    cfg.verify.reps = 1;
    cfg
}

fn main() -> anyhow::Result<()> {
    let _smoke = std::env::args().any(|a| a == "--test");
    let n = env_usize("FBO_N", 64);

    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let tmp = std::env::temp_dir().join(format!("fbo-bench-telemetry-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp)?;
    let replay_cache = tmp.join("cache-replay");
    let fresh_cache = tmp.join("cache-fresh");
    let src = apps::sensor_fusion_app(n);

    println!("== telemetry trace gate: sensor_fusion, n={n} ==");

    // Untraced fresh run: the reference decision bytes.
    let service = OffloadService::start(config(&artifacts, &replay_cache))?;
    service.cache().clear()?;
    let t0 = Instant::now();
    let untraced = service.submit(&src, "main").wait()?;
    let untraced_wall = t0.elapsed();
    assert!(!untraced.from_cache);
    service.shutdown();
    println!("untraced fresh: {}", fmt_duration(untraced_wall));

    // Traced fresh run on a cold cache: full span trace into the sink.
    let mut cfg = config(&artifacts, &fresh_cache);
    cfg.telemetry.trace_out = Some(tmp.join("fresh.trace.jsonl"));
    let service = OffloadService::start(cfg)?;
    service.cache().clear()?;
    let t0 = Instant::now();
    let traced = service.submit(&src, "main").wait()?;
    let traced_wall = t0.elapsed();
    assert!(!traced.from_cache);
    let recorder = service.recorder().clone();
    service.shutdown();
    println!("traced fresh:   {}", fmt_duration(traced_wall));

    // Every sink line must decode and re-encode byte-identically.
    let sink = std::fs::read_to_string(tmp.join("fresh.trace.jsonl"))?;
    let mut sink_records = 0usize;
    for line in sink.lines() {
        let rec = TraceRecord::from_jsonl_line(line)?;
        assert_eq!(rec.to_jsonl_line(), line, "JSONL round-trip must be byte-identical");
        sink_records += 1;
    }
    assert_eq!(recorder.dropped(), 0, "ring must hold the whole single-job trace");
    assert_eq!(sink_records, recorder.len(), "sink must mirror the ring");

    // The Chrome export parses, and carries one "X" span per stage.
    let chrome = json::parse(&recorder.chrome_trace())?;
    let events = match chrome.get("traceEvents")? {
        Json::Arr(events) => events,
        other => anyhow::bail!("traceEvents must be an array, got {other:?}"),
    };
    let spans = events
        .iter()
        .filter(|e| matches!(e.get("ph"), Ok(Json::Str(ph)) if ph == "X"))
        .count();
    assert_eq!(spans, 7, "one complete span per pipeline stage");

    // Passivity: the traced service replays the untraced decision
    // byte-for-byte — telemetry config is outside every fingerprint.
    let mut cfg = config(&artifacts, &replay_cache);
    cfg.telemetry.trace_out = Some(tmp.join("replay.trace.jsonl"));
    let service = OffloadService::start(cfg)?;
    let replayed = service.submit(&src, "main").wait()?;
    assert!(replayed.from_cache, "telemetry must not shift any cache fingerprint");
    let byte_identical = replayed.report_json == untraced.report_json;
    assert!(byte_identical, "traced replay must be byte-identical to the untraced decision");
    service.shutdown();
    println!("replay under tracing: byte-identical ({} trace records)", sink_records);

    let out = Json::obj(vec![
        ("bench", Json::str("telemetry_trace")),
        ("n", Json::num(n as f64)),
        ("untraced_secs", Json::num(untraced_wall.as_secs_f64())),
        ("traced_secs", Json::num(traced_wall.as_secs_f64())),
        ("trace_records", Json::num(sink_records as f64)),
        ("spans", Json::num(spans as f64)),
        ("byte_identical", Json::Bool(byte_identical)),
    ]);
    let bench_path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_telemetry.json");
    std::fs::write(&bench_path, json::to_string_pretty(&out))?;
    println!("recorded {}", bench_path.display());

    std::fs::remove_dir_all(&tmp).ok();
    Ok(())
}
