//! Bench: serial vs pooled vs distributed-fleet pattern verification.
//!
//! The paper's verification step measures every candidate pattern on one
//! machine; `verify_parallel` already fans patterns across sibling
//! engines in-process. The fleet tier takes the same step across process
//! (and, in production, machine) boundaries: this bench spawns two
//! `fbo worker --stdio` child processes, deals the sensor-fusion app's
//! measurement batches to them over the `fbo-fleet-v1` wire protocol,
//! and asserts the *decision* is byte-identical to the serial run — the
//! fleet buys wall-clock and capacity, never a different answer.
//!
//! Run: `cargo bench --bench fleet_verify` (add `-- --test` for the CI
//! smoke mode: 1 rep, no wall-clock assertion — timing on shared runners
//! is noise).
//! Records: `BENCH_fleet.json` at the repo root.

use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use fbo::coordinator::{apps, Coordinator, OffloadReport, SerialExecutor};
use fbo::fleet::{FleetEndpoint, FleetExecutor, FleetRegistry};
use fbo::metrics::Table;
use fbo::patterndb::json::{self, Json};
use fbo::service::MeasurePool;

const FLEET_WORKERS: usize = 2;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn pattern_labels(r: &OffloadReport) -> Vec<String> {
    r.outcome.tried.iter().map(|p| p.label.clone()).collect()
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test");
    let n = env_usize("FBO_N", 64);
    let reps = env_usize("FBO_REPS", if smoke { 1 } else { 3 });
    let parallel = env_usize("FBO_VERIFY_PARALLEL", 4).max(2);
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let src = apps::sensor_fusion_app(n);

    println!(
        "== fleet verify: sensor-fusion app (3 blocks) at n={n}, reps={reps}, \
         {FLEET_WORKERS} stdio workers =="
    );

    // Serial: one engine, patterns back to back. Warm once so artifact
    // compiles (cached in the engine) are not billed to any executor.
    let mut serial = Coordinator::open(&artifacts)?;
    serial.verify.reps = reps;
    let _ = serial.offload(&src, "main")?;
    let t0 = Instant::now();
    let serial_report = serial.offload(&src, "main")?;
    let serial_secs = t0.elapsed().as_secs_f64();

    // Pooled: in-process measure-only siblings (the `--verify-parallel`
    // tier the fleet falls back to).
    let mut pooled = Coordinator::open(&artifacts)?;
    pooled.verify.reps = reps;
    let pool = MeasurePool::start(&artifacts, parallel - 1)?;
    pooled.executor = Some(Rc::new(pool.executor(pooled.engine.clone(), parallel)));
    let _ = pooled.offload(&src, "main")?;
    let t0 = Instant::now();
    let pooled_report = pooled.offload(&src, "main")?;
    let pooled_secs = t0.elapsed().as_secs_f64();

    // Fleet: two spawned `fbo worker --stdio` children, one engine each,
    // fed whole measurement batches over length-prefixed JSON frames.
    let endpoint = format!(
        "stdio:{} worker --stdio --artifacts {}",
        env!("CARGO_BIN_EXE_fbo"),
        artifacts.display()
    );
    let endpoints: Vec<FleetEndpoint> = (0..FLEET_WORKERS)
        .map(|_| FleetEndpoint::parse(&endpoint))
        .collect::<anyhow::Result<_>>()?;
    let mut fleeted = Coordinator::open(&artifacts)?;
    fleeted.verify.reps = reps;
    let registry = FleetRegistry::connect(&endpoints);
    anyhow::ensure!(
        registry.live_count() == FLEET_WORKERS,
        "fleet workers failed to start: {:?}",
        registry.rejected()
    );
    let fallback = Rc::new(SerialExecutor::new(fleeted.engine.clone()));
    let exec = Rc::new(FleetExecutor::new(registry, fallback));
    fleeted.executor = Some(exec.clone());
    let _ = fleeted.offload(&src, "main")?; // warm the children's engines
    let t0 = Instant::now();
    let fleet_report = fleeted.offload(&src, "main")?;
    let fleet_secs = t0.elapsed().as_secs_f64();
    let (remote, local, redeals) =
        (exec.stats().remote(), exec.stats().local(), exec.stats().redeals());

    // The determinism contract, across all three executors.
    let identical = serial_report.outcome.best_enabled == pooled_report.outcome.best_enabled
        && serial_report.outcome.best_enabled == fleet_report.outcome.best_enabled
        && pattern_labels(&serial_report) == pattern_labels(&pooled_report)
        && pattern_labels(&serial_report) == pattern_labels(&fleet_report);
    assert!(
        identical,
        "serial/pooled/fleet must pick the same pattern: {:?} vs {:?} vs {:?}",
        serial_report.outcome.best_enabled,
        pooled_report.outcome.best_enabled,
        fleet_report.outcome.best_enabled
    );
    assert!(remote > 0, "the fleet run must measure patterns remotely");

    let mut table = Table::new(&["executor", "wall (s)", "patterns", "best speedup"]);
    for (name, secs, report) in [
        ("serial", serial_secs, &serial_report),
        ("pooled", pooled_secs, &pooled_report),
        ("fleet(2 stdio)", fleet_secs, &fleet_report),
    ] {
        table.row(&[
            name.to_string(),
            format!("{secs:.3}"),
            report.outcome.tried.len().to_string(),
            format!("{:.1}", report.best_speedup()),
        ]);
    }
    print!("{}", table.render());
    println!("fleet measurements: {remote} remote, {local} local, {redeals} re-deals");

    let out = Json::obj(vec![
        ("bench", Json::str("fleet_verify")),
        ("n", Json::num(n as f64)),
        ("reps", Json::num(reps as f64)),
        ("fleet_workers", Json::num(FLEET_WORKERS as f64)),
        ("transport", Json::str("stdio")),
        (
            "patterns",
            Json::Arr(pattern_labels(&serial_report).iter().map(Json::str).collect()),
        ),
        ("serial_secs", Json::num(serial_secs)),
        ("pooled_secs", Json::num(pooled_secs)),
        ("fleet_secs", Json::num(fleet_secs)),
        ("remote_measurements", Json::num(remote as f64)),
        ("local_measurements", Json::num(local as f64)),
        ("redeals", Json::num(redeals as f64)),
        ("best_speedup", Json::num(serial_report.best_speedup())),
        ("decisions_identical", Json::Bool(identical)),
    ]);
    let bench_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_fleet.json");
    std::fs::write(&bench_path, json::to_string_pretty(&out))?;
    println!("recorded {}", bench_path.display());

    // Smoke mode skips the wall-clock thesis: 1-rep timings on a noisy
    // shared runner prove nothing, and child processes cold-compile.
    if !smoke {
        assert!(
            fleet_secs < serial_secs,
            "fleet verify ({fleet_secs:.3}s) must beat serial ({serial_secs:.3}s)"
        );
    }
    Ok(())
}
