//! Bench: device-resident data plane on the sensor-fusion pipeline.
//!
//! `sensor_fusion_app` chains fft2d -> matmul -> ludcmp with a genuine
//! inter-block tensor handoff (fft2d's output spectrum is matmul's
//! input), so it is the evaluation app residency exists for. Three runs
//! gate three invariants:
//!
//! 1. round-trip baseline (`--resident-bytes 0`, the default) — no
//!    residency section, no elided bytes: the pre-residency pipeline;
//! 2. resident run (64 MiB budget) — the report upgrades to v5, the
//!    handoff elides host<->device bytes (> 0), arbitration credits the
//!    saved PCIe transfer time, and the paid byte total drops below the
//!    round-trip baseline;
//! 3. passivity — a zero-budget run on the engine the resident run
//!    warmed decides identically to the fresh baseline and pays exactly
//!    the same bytes (the plane uninstalls, nothing leaks).
//!
//! Run: `cargo bench --bench residency` (add `-- --test` for the CI
//! smoke mode: 1 rep).
//! Records: `BENCH_residency.json` at the repo root.

use std::path::PathBuf;

use fbo::coordinator::{apps, report_json, Coordinator, OffloadReport};
use fbo::metrics::{fmt_bytes, Table};
use fbo::patterndb::json::{self, Json};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn paid_bytes(r: &OffloadReport) -> u64 {
    r.outcome.tried.iter().map(|p| p.traffic.bytes_in + p.traffic.bytes_out).sum()
}

fn elided_bytes(r: &OffloadReport) -> u64 {
    r.outcome.tried.iter().map(|p| p.traffic.elided_in + p.traffic.elided_out).sum()
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test");
    let n = env_usize("FBO_N", 64);
    let reps = env_usize("FBO_REPS", if smoke { 1 } else { 3 });
    let budget = 64u64 << 20;

    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let src = apps::sensor_fusion_app(n);
    let mut c = Coordinator::open(&artifacts)?;
    c.verify.reps = reps;

    println!("== device residency: sensor_fusion_app at n={n} ==");

    // 1. Round-trip baseline: the default pipeline stages every input in
    // and reads every output back, and records no residency section.
    let off = c.offload(&src, "main")?;
    let off_json = report_json::report_to_string(&off);
    assert!(
        !off_json.contains("\"residency\""),
        "the default (zero-budget) report must carry no residency section"
    );
    assert!(off.arbitration.residency.is_none(), "no plane, no residue");
    assert_eq!(elided_bytes(&off), 0, "no plane, no elided traffic");

    // 2. Resident run: the same coordinator under a nonzero budget.
    c.resident_bytes = budget;
    let resident = c.offload(&src, "main")?;
    let resident_json = report_json::report_to_string(&resident);
    assert!(
        resident_json.contains("fbo-offload-report-v5"),
        "a residency-shaped run must emit the v5 report"
    );
    let residue = resident
        .arbitration
        .residency
        .as_ref()
        .expect("a nonzero budget must attach the residency residue");
    assert_eq!(residue.budget_bytes, budget);
    let elided = elided_bytes(&resident);
    assert!(elided > 0, "the fft2d->matmul handoff must elide transfers");
    assert!(
        residue.total_saved_transfer_secs > 0.0,
        "arbitration must credit the saved PCIe transfer time"
    );
    assert!(
        paid_bytes(&resident) < paid_bytes(&off),
        "the resident path must pay fewer PCIe bytes than the round trip"
    );

    // 3. Passivity: zero budget on the warmed engine uninstalls the
    // plane — same decision, same paid bytes as the fresh baseline.
    c.resident_bytes = 0;
    let off_again = c.offload(&src, "main")?;
    assert!(off_again.arbitration.residency.is_none());
    assert_eq!(
        off_again.outcome.best_enabled, off.outcome.best_enabled,
        "zero-budget decisions must match the pre-residency pipeline"
    );
    assert_eq!(off_again.arbitration.backend, off.arbitration.backend);
    assert_eq!(elided_bytes(&off_again), 0, "the warmed engine must elide nothing at budget 0");
    for (a, b) in off_again.outcome.tried.iter().zip(&off.outcome.tried) {
        assert_eq!(
            (a.traffic.bytes_in, a.traffic.bytes_out, a.traffic.dispatches),
            (b.traffic.bytes_in, b.traffic.bytes_out, b.traffic.dispatches),
            "{}: zero-budget traffic must be byte-identical to the baseline",
            a.label
        );
    }

    let mut table = Table::new(&["mode", "backend", "paid bytes", "elided bytes", "saved/run"]);
    table.row(&[
        "round-trip".to_string(),
        off.arbitration.backend.as_str().to_string(),
        fmt_bytes(paid_bytes(&off)),
        fmt_bytes(0),
        "-".to_string(),
    ]);
    table.row(&[
        format!("resident ({})", fmt_bytes(budget)),
        resident.arbitration.backend.as_str().to_string(),
        fmt_bytes(paid_bytes(&resident)),
        fmt_bytes(elided),
        format!("{:.3}us", residue.total_saved_transfer_secs * 1e6),
    ]);
    print!("{}", table.render());
    println!(
        "residency elided {} of host<->device traffic ({} blocks credited)",
        fmt_bytes(elided),
        residue.blocks.len()
    );

    let out = Json::obj(vec![
        ("bench", Json::str("residency")),
        ("app", Json::str("sensor_fusion_app")),
        ("n", Json::num(n as f64)),
        ("reps", Json::num(reps as f64)),
        ("budget_bytes", Json::num(budget as f64)),
        ("off_paid_bytes", Json::num(paid_bytes(&off) as f64)),
        ("off_elided_bytes", Json::num(0.0)),
        ("resident_paid_bytes", Json::num(paid_bytes(&resident) as f64)),
        ("resident_elided_bytes", Json::num(elided as f64)),
        ("saved_transfer_secs", Json::num(residue.total_saved_transfer_secs)),
        ("credited_blocks", Json::num(residue.blocks.len() as f64)),
        ("report_version_resident", Json::str("fbo-offload-report-v5")),
        ("off_decision_identical", Json::Bool(true)),
    ]);
    let bench_path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_residency.json");
    std::fs::write(&bench_path, json::to_string_pretty(&out))?;
    println!("recorded {}", bench_path.display());
    Ok(())
}
