//! Bench: GPU-vs-FPGA backend arbitration over the evaluation apps.
//!
//! For each app, runs the full Steps 1–3 pipeline under `--target auto`
//! and records what Step 3b decided: the measured PJRT ("GPU") device
//! seconds of the chosen pattern, the FPGA estimate from the device
//! model, the chosen backend, and the simulated toolchain hours the
//! decision charged. The paper's Table-2 shape: which blocks land on
//! which accelerator, and what the narrowing + pre-check saved.
//!
//! Run: `cargo bench --bench backend_arbitration`
//! Records: `BENCH_backend.json` at the repo root.

use std::path::PathBuf;

use fbo::coordinator::{apps, Backend, Coordinator};
use fbo::metrics::{fmt_duration, fmt_hours, Table};
use fbo::patterndb::json::{self, Json};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test");
    let n = env_usize("FBO_N", 64);
    let reps = env_usize("FBO_REPS", if smoke { 1 } else { 3 });

    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut c = Coordinator::open(&artifacts)?;
    c.verify.reps = reps;

    println!("== backend arbitration: eval apps at n={n}, --target auto ==");
    let mut table = Table::new(&[
        "app",
        "backend",
        "gpu device (measured)",
        "fpga est (modeled)",
        "toolchain",
        "speedup",
    ]);
    let mut rows = Vec::new();
    let mut chosen = Vec::new();

    for (name, src) in apps::all(n) {
        let report = c.request(&src, "main").run()?;
        let arb = &report.arbitration;
        // The app's accelerated block (eval apps have exactly one winner).
        let block = arb
            .blocks
            .iter()
            .zip(&report.outcome.best_enabled)
            .find(|(_, &on)| on)
            .map(|(b, _)| b);
        let (gpu_dev, fpga_est) = match block {
            Some(b) => (
                b.gpu_device_secs,
                b.fpga.as_ref().filter(|f| f.precheck_ok).map(|f| f.est_secs),
            ),
            None => (0.0, None),
        };
        chosen.push(arb.backend);
        table.row(&[
            name.clone(),
            arb.backend.as_str().to_string(),
            fmt_duration(std::time::Duration::from_secs_f64(gpu_dev)),
            fpga_est
                .map(|s| fmt_duration(std::time::Duration::from_secs_f64(s)))
                .unwrap_or_else(|| "-".to_string()),
            fmt_hours(arb.simulated_hours),
            format!("{:.1}", report.best_speedup()),
        ]);
        rows.push(Json::obj(vec![
            ("app", Json::str(&name)),
            ("backend", Json::str(arb.backend.as_str())),
            ("gpu_device_secs", Json::num(gpu_dev)),
            (
                "fpga_est_secs",
                fpga_est.map(Json::num).unwrap_or(Json::Null),
            ),
            ("simulated_hours", Json::num(arb.simulated_hours)),
            ("best_speedup", Json::num(report.best_speedup())),
        ]));
    }
    print!("{}", table.render());

    let fpga_count = chosen.iter().filter(|&&b| b == Backend::Fpga).count();
    let gpu_count = chosen.iter().filter(|&&b| b == Backend::Gpu).count();
    println!("chosen: {fpga_count} fpga, {gpu_count} gpu, of {} apps", chosen.len());

    let out = Json::obj(vec![
        ("bench", Json::str("backend_arbitration")),
        ("n", Json::num(n as f64)),
        ("reps", Json::num(reps as f64)),
        ("apps", Json::Arr(rows)),
        ("fpga_count", Json::num(fpga_count as f64)),
        ("gpu_count", Json::num(gpu_count as f64)),
    ]);
    let bench_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_backend.json");
    std::fs::write(&bench_path, json::to_string_pretty(&out))?;
    println!("recorded {}", bench_path.display());

    // The arbitration thesis at eval scale: the DB-registered IP cores
    // (FFT, LU) beat the measured PJRT path for at least one app, while
    // apps without a registered core (matmul) stay on the GPU.
    assert!(fpga_count >= 1, "expected at least one app to arbitrate to the FPGA");
    assert!(gpu_count >= 1, "expected at least one app to stay on the GPU");
    Ok(())
}
