//! Bench: power-aware arbitration over the evaluation apps.
//!
//! For each app, Steps 1–3 run once; the saved `Verified` measurements
//! are then arbitrated three ways from the same artifact:
//!
//! 1. `--power-policy perf` (the default) — the decision-identity gate:
//!    the report must serialize as v2 with no power section, and the
//!    decision must be completely invariant to the wattage model (watts
//!    cannot influence a time-only arbitration), which is exactly the
//!    pre-power behavior;
//! 2. `--power-policy perf-per-watt` — modeled joules decide
//!    (arXiv:2110.11520's selection rule); the bench records per-block
//!    energies and whether the backend flipped vs the perf decision;
//! 3. `--power-policy cap:50` — the 75 W GPU is excluded, the 40 W FPGA
//!    and the CPU remain.
//!
//! Run: `cargo bench --bench power_arbitration` (add `-- --test` for the
//! CI smoke mode: 1 rep).
//! Records: `BENCH_power.json` at the repo root.

use std::path::PathBuf;

use fbo::coordinator::{apps, Backend, Coordinator, PowerModel, PowerPolicy};
use fbo::metrics::Table;
use fbo::patterndb::json::{self, Json};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test");
    let n = env_usize("FBO_N", 64);
    let reps = env_usize("FBO_REPS", if smoke { 1 } else { 3 });

    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut c = Coordinator::open(&artifacts)?;
    c.verify.reps = reps;

    println!("== power arbitration: eval apps at n={n}, --target auto ==");
    let mut table = Table::new(&[
        "app",
        "perf backend",
        "perf-per-watt backend",
        "cap:50 backend",
        "gpu energy (winner)",
        "fpga energy (winner)",
    ]);
    let mut rows = Vec::new();
    let mut flips = 0usize;

    for (name, src) in apps::all(n) {
        let req = c.request(&src, "main");
        let verified =
            req.parse()?.discover(&req)?.reconcile(&req)?.verify(&req)?;

        // 1. Default perf path.
        let perf = verified.arbitrate(&req)?;
        let perf_report = perf.report();
        let perf_json = fbo::coordinator::report_json::report_to_string(&perf_report);
        assert!(
            perf_json.contains("fbo-offload-report-v2"),
            "{name}: the default policy must emit v2 report bytes"
        );
        assert!(
            !perf_json.contains("\"power\""),
            "{name}: the default policy must record no power section"
        );

        // Decision-identity gate: a perf arbitration is a *time* decision,
        // so the wattage model must be unable to influence any of it —
        // same per-block backends, same overall backend, same request
        // times — which is precisely the pre-power arbitration behavior.
        let mut hot = PowerModel::builtin();
        hot.gpu.active_watts *= 10.0;
        hot.fpga.active_watts *= 10.0;
        hot.cpu.active_watts *= 10.0;
        let hot_req = c.request(&src, "main").with_power_model(hot);
        let perf_hot = verified.arbitrate(&hot_req)?;
        assert_eq!(
            perf.arbitration, perf_hot.arbitration,
            "{name}: perf decisions must be wattage-independent"
        );

        // 2. Performance-per-watt.
        let ppw_req =
            c.request(&src, "main").with_power_policy(PowerPolicy::PerfPerWatt);
        let ppw = verified.power_score(&ppw_req)?.arbitrate(&ppw_req)?;
        let residue = ppw
            .arbitration
            .power
            .as_ref()
            .expect("non-default policy must record the power residue");

        // 3. Wattage cap below the GPU's draw.
        let cap_req =
            c.request(&src, "main").with_power_policy(PowerPolicy::Cap(50.0));
        let cap = verified.power_score(&cap_req)?.arbitrate(&cap_req)?;
        assert!(
            cap.arbitration.blocks.iter().all(|b| b.backend != Backend::Gpu),
            "{name}: no block may land on the 75 W GPU under cap:50"
        );

        let flipped = ppw.arbitration.backend != perf.arbitration.backend;
        flips += flipped as usize;

        // Energy of the winning block, when one offloaded.
        let win = ppw
            .arbitration
            .blocks
            .iter()
            .zip(residue.blocks.iter())
            .find(|(b, _)| b.backend != Backend::Cpu)
            .map(|(_, e)| e);
        let fmt_j = |v: Option<f64>| match v {
            Some(j) => format!("{:.3} mJ", j * 1e3),
            None => "-".to_string(),
        };
        let (gpu_j, fpga_j) = match win {
            Some(e) => (e.gpu_energy_j, e.fpga_energy_j),
            None => (None, None),
        };
        table.row(&[
            name.clone(),
            perf.arbitration.backend.as_str().to_string(),
            ppw.arbitration.backend.as_str().to_string(),
            cap.arbitration.backend.as_str().to_string(),
            fmt_j(gpu_j),
            fmt_j(fpga_j),
        ]);
        rows.push(Json::obj(vec![
            ("app", Json::str(&name)),
            ("perf_backend", Json::str(perf.arbitration.backend.as_str())),
            ("ppw_backend", Json::str(ppw.arbitration.backend.as_str())),
            ("cap50_backend", Json::str(cap.arbitration.backend.as_str())),
            ("flipped", Json::Bool(flipped)),
            ("gpu_energy_j", gpu_j.map(Json::num).unwrap_or(Json::Null)),
            ("fpga_energy_j", fpga_j.map(Json::num).unwrap_or(Json::Null)),
            ("gpu_watts", Json::num(residue.gpu_watts)),
            ("fpga_watts", Json::num(residue.fpga_watts)),
            ("perf_decisions_identical", Json::Bool(true)),
        ]));
    }
    print!("{}", table.render());
    println!("perf-per-watt flipped {flips} app(s) vs the perf decision");

    let out = Json::obj(vec![
        ("bench", Json::str("power_arbitration")),
        ("n", Json::num(n as f64)),
        ("reps", Json::num(reps as f64)),
        ("apps", Json::Arr(rows)),
        ("ppw_flips", Json::num(flips as f64)),
    ]);
    let bench_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_power.json");
    std::fs::write(&bench_path, json::to_string_pretty(&out))?;
    println!("recorded {}", bench_path.display());
    Ok(())
}
