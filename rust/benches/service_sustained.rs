//! Bench: sustained open-loop load against the admission-controlled
//! service with a cache budget pinned *below* the working set.
//!
//! Arrivals are open-loop (a fixed inter-arrival clock, not request →
//! response → request), drawn from a churning key population larger than
//! the cache budget admits, so the run continuously exercises all three
//! production mechanisms at once: tier-aware eviction (every gc
//! checkpoint must land at or under budget), admission control (the
//! arrival rate outruns the verify rate, so the bounded queues must
//! shed), and byte-identical replay for whatever survives.
//!
//! Always asserted, smoke or not: cache bytes <= budget at every gc
//! checkpoint, `submitted == completed + failed + shed`, `failed == 0`,
//! at least one shed, and byte-identical replay after eviction pressure.
//! The wall-clock thesis (cache hits are much faster than verification)
//! is skipped in smoke mode where timings prove nothing.
//!
//! Run: `cargo bench --bench service_sustained`
//! Records: `BENCH_sustained.json` at the repo root.

use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use fbo::coordinator::apps;
use fbo::ga::rng::Rng;
use fbo::metrics::{percentile, Table};
use fbo::patterndb::json::{self, Json};
use fbo::service::{CacheBudget, JobHandle, JobRejected, OffloadService, ServiceConfig};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

enum Outcome {
    Done { latency: Duration, from_cache: bool },
    Shed,
    Failed,
}

const COLLECTORS: usize = 4;

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test");
    let n = env_usize("FBO_N", 64);
    let workers = env_usize("FBO_JOBS", 2);
    let keys = env_usize("FBO_SUSTAIN_KEYS", if smoke { 6 } else { 24 });
    let arrivals = env_usize("FBO_SUSTAIN_ARRIVALS", if smoke { 40 } else { 400 });
    let interval_ms = env_usize("FBO_SUSTAIN_INTERVAL_MS", if smoke { 5 } else { 10 }) as u64;
    let checkpoint_every = env_usize("FBO_SUSTAIN_CHECKPOINT", 25);

    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let cache_dir =
        std::env::temp_dir().join(format!("fbo-bench-sustained-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);

    let mut cfg = ServiceConfig::new(artifacts);
    cfg.cache_dir = Some(cache_dir.clone());
    cfg.workers = workers;
    cfg.verify.reps = 1;
    cfg.admission.queue_limit = 4;

    // Churning key population over one prebuilt kernel set: each unused
    // trailing function shifts the AST hash (a distinct cache key) while
    // the offloadable blocks keep using the size-`n` artifacts.
    let base = apps::matmul_app(n);
    let population: Vec<String> =
        (0..keys).map(|i| format!("{base}\nint churn_{i}() {{ return {i}; }}\n")).collect();

    println!("== sustained load: {arrivals} arrivals / {keys} keys, {workers} workers ==");
    let service = OffloadService::start(cfg)?;
    service.cache().clear()?; // guaranteed cold across bench re-runs

    // Warm phase: verify a seed subset to size the working set, then pin
    // the budget below it so the sustained phase runs under standing
    // eviction pressure.
    let seeds = 3.min(keys);
    let seed_jobs: Vec<(String, String)> =
        population.iter().take(seeds).map(|s| (s.clone(), "main".to_string())).collect();
    for r in service.run_batch(&seed_jobs) {
        r?;
    }
    let per_key = service.cache().usage().bytes / seeds as u64;
    let working_set = per_key * keys as u64;
    let budget = CacheBudget { max_bytes: Some((working_set / 2).max(per_key)), max_entries: None };
    service.cache().set_budget(budget);
    service.cache().gc(budget, false)?;
    println!(
        "working set ~{working_set} bytes over {keys} keys; budget {} bytes",
        budget.max_bytes.unwrap()
    );

    // Collector threads await responses off the arrival thread, so a slow
    // job never paces the arrival clock (that is what makes it open-loop).
    let outcomes: Arc<Mutex<Vec<Outcome>>> = Arc::new(Mutex::new(Vec::new()));
    let (tx, rx) = mpsc::channel::<(Instant, JobHandle)>();
    let rx = Arc::new(Mutex::new(rx));
    let collectors: Vec<_> = (0..COLLECTORS)
        .map(|_| {
            let rx = Arc::clone(&rx);
            let outcomes = Arc::clone(&outcomes);
            std::thread::spawn(move || loop {
                let msg = rx.lock().expect("collector rx lock").recv();
                let Ok((t0, handle)) = msg else { break };
                let outcome = match handle.wait() {
                    Ok(done) => {
                        Outcome::Done { latency: t0.elapsed(), from_cache: done.from_cache }
                    }
                    Err(e) if e.downcast_ref::<JobRejected>().is_some() => Outcome::Shed,
                    Err(_) => Outcome::Failed,
                };
                outcomes.lock().expect("collector outcome lock").push(outcome);
            })
        })
        .collect();

    // Sustained phase: open-loop arrivals with periodic gc checkpoints.
    let mut rng = Rng::new(0x5eed);
    let clients = ["alpha", "beta", "gamma"];
    let mut checkpoints = 0usize;
    let t_start = Instant::now();
    for i in 0..arrivals {
        let key = rng.below(keys);
        let t0 = Instant::now();
        let handle = service.submit_as(&population[key], "main", clients[i % clients.len()]);
        tx.send((t0, handle)).expect("collector thread alive");
        if (i + 1) % checkpoint_every == 0 {
            let out = service.cache().gc(budget, false)?;
            assert!(
                out.bytes_after <= budget.max_bytes.unwrap(),
                "budget invariant violated at checkpoint: {} bytes > {:?}",
                out.bytes_after,
                budget
            );
            checkpoints += 1;
        }
        std::thread::sleep(Duration::from_millis(interval_ms));
    }
    drop(tx);
    for c in collectors {
        c.join().expect("collector thread");
    }
    let wall = t_start.elapsed();

    // Replay contract under eviction: whatever the budget evicted, the
    // next verification of a key must replay byte-identically afterwards.
    let probe = service.submit_as(&population[0], "main", "replay-probe").wait()?;
    let replay = service.submit_as(&population[0], "main", "replay-probe").wait()?;
    assert!(replay.from_cache, "second probe must replay from the cache");
    assert_eq!(
        replay.report_json, probe.report_json,
        "byte-identical replay under eviction pressure"
    );

    // Accounting invariant: shed is its own outcome, nothing is lost and
    // nothing is double-counted.
    let stats = service.stats();
    assert_eq!(
        stats.submitted,
        stats.completed + stats.failed + stats.jobs_shed,
        "submitted must equal completed + failed + shed after drain"
    );
    assert_eq!(stats.failed, 0, "sustained load must not fail jobs");
    assert!(stats.jobs_shed >= 1, "open-loop arrivals above the verify rate must shed");

    let outcomes =
        Arc::try_unwrap(outcomes).ok().expect("collectors joined").into_inner().expect("lock");
    let mut latencies: Vec<Duration> = Vec::new();
    let mut hit_lat: Vec<Duration> = Vec::new();
    let mut miss_lat: Vec<Duration> = Vec::new();
    let (mut done_ct, mut shed_ct, mut failed_ct) = (0u64, 0u64, 0u64);
    for o in &outcomes {
        match o {
            Outcome::Done { latency, from_cache } => {
                done_ct += 1;
                latencies.push(*latency);
                if *from_cache {
                    hit_lat.push(*latency);
                } else {
                    miss_lat.push(*latency);
                }
            }
            Outcome::Shed => shed_ct += 1,
            Outcome::Failed => failed_ct += 1,
        }
    }
    assert_eq!(done_ct + shed_ct + failed_ct, arrivals as u64);
    assert_eq!(failed_ct, 0);

    let p50 = percentile(&latencies, 50.0).unwrap_or_default();
    let p99 = percentile(&latencies, 99.0).unwrap_or_default();
    let p999 = percentile(&latencies, 99.9).unwrap_or_default();
    let shed_rate = shed_ct as f64 / arrivals as f64;
    let probes = stats.cache_hits + stats.cache_misses;
    let hit_rate = stats.cache_hits as f64 / probes.max(1) as f64;
    let usage = service.cache().usage();
    let evictions = service.cache().stats().evictions_total();

    let lat_row = format!("{:.1}ms / {:.1}ms / {:.1}ms", ms(p50), ms(p99), ms(p999));
    let bytes_row = format!("{} ({})", usage.bytes, budget.max_bytes.unwrap());
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["arrivals".into(), format!("{arrivals} over {:.2}s", wall.as_secs_f64())]);
    t.row(&["completed / shed".into(), format!("{done_ct} / {shed_ct}")]);
    t.row(&["latency p50/p99/p999".into(), lat_row]);
    t.row(&["shed rate".into(), format!("{:.1}%", shed_rate * 100.0)]);
    t.row(&["cache hit rate".into(), format!("{:.1}%", hit_rate * 100.0)]);
    t.row(&["cache bytes (budget)".into(), bytes_row]);
    t.row(&["evictions / gc checkpoints".into(), format!("{evictions} / {checkpoints}")]);
    print!("{}", t.render());

    let out = Json::obj(vec![
        ("bench", Json::str("service_sustained")),
        ("n", Json::num(n as f64)),
        ("workers", Json::num(workers as f64)),
        ("keys", Json::num(keys as f64)),
        ("arrivals", Json::num(arrivals as f64)),
        ("interval_ms", Json::num(interval_ms as f64)),
        ("wall_secs", Json::num(wall.as_secs_f64())),
        ("submitted", Json::num(stats.submitted as f64)),
        ("completed", Json::num(stats.completed as f64)),
        ("shed", Json::num(stats.jobs_shed as f64)),
        ("failed", Json::num(stats.failed as f64)),
        ("shed_rate", Json::num(shed_rate)),
        ("cache_hit_rate", Json::num(hit_rate)),
        ("latency_p50_secs", Json::num(p50.as_secs_f64())),
        ("latency_p99_secs", Json::num(p99.as_secs_f64())),
        ("latency_p999_secs", Json::num(p999.as_secs_f64())),
        ("budget_bytes", Json::num(budget.max_bytes.unwrap() as f64)),
        ("working_set_bytes", Json::num(working_set as f64)),
        ("cache_bytes_final", Json::num(usage.bytes as f64)),
        ("cache_entries_final", Json::num(usage.entries as f64)),
        ("evictions_total", Json::num(evictions as f64)),
        ("gc_checkpoints", Json::num(checkpoints as f64)),
        ("byte_identical_replay", Json::Bool(true)),
        ("budget_violations", Json::num(0.0)),
    ]);
    let bench_path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_sustained.json");
    std::fs::write(&bench_path, json::to_string_pretty(&out))?;
    println!("recorded {}", bench_path.display());

    service.shutdown();
    std::fs::remove_dir_all(&cache_dir).ok();

    // Wall-clock thesis — skipped in smoke mode, where timings on a noisy
    // shared runner prove nothing (the invariants above still held).
    if !smoke {
        let hit_p50 = percentile(&hit_lat, 50.0).unwrap_or_default().as_secs_f64();
        let miss_p50 = percentile(&miss_lat, 50.0).unwrap_or_default().as_secs_f64().max(1e-9);
        assert!(
            !hit_lat.is_empty() && hit_p50 * 5.0 <= miss_p50,
            "cache hits must be >=5x faster than verification \
             (hit p50 {hit_p50:.4}s vs miss p50 {miss_p50:.4}s)"
        );
    }
    Ok(())
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}
