//! Bench: offload-service throughput, cold decision cache vs warm.
//!
//! Cold = every job runs the paper's full pipeline (discovery + measured
//! pattern search). Warm = every job replays a previously verified
//! decision from the content-addressed cache. The gap is the whole point
//! of the service tier: verification is a one-time cost, serving is not.
//!
//! Also checks the cache contract: a warm read must be **byte-identical**
//! to the serialization produced when the decision was first computed.
//! The stats line includes the per-stage latency means collected through
//! the pipeline's `StageObserver` hook, so the cold pass shows where the
//! verification time actually goes.
//!
//! Run: `cargo bench --bench service_throughput`
//! Records: `BENCH_service.json` at the repo root.

use std::path::PathBuf;
use std::time::Instant;

use fbo::coordinator::apps;
use fbo::metrics::{fmt_duration, Table};
use fbo::patterndb::json::{self, Json};
use fbo::service::{OffloadService, ServiceConfig};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test");
    let n = env_usize("FBO_N", 64);
    let repeat = env_usize("FBO_REPEAT", if smoke { 1 } else { 2 });
    let workers = env_usize("FBO_JOBS", 2);

    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let cache_dir =
        std::env::temp_dir().join(format!("fbo-bench-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);

    let mut cfg = ServiceConfig::new(artifacts);
    cfg.cache_dir = Some(cache_dir.clone());
    cfg.workers = workers;
    cfg.verify.reps = 1;

    // The five evaluation apps, `repeat`-fold (a batch with duplicates is
    // the realistic shape: many users submit the same application).
    let mut batch: Vec<(String, String)> = Vec::new();
    for _ in 0..repeat {
        batch.extend(apps::all(n).into_iter().map(|(_, src)| (src, "main".to_string())));
    }

    println!("== service throughput: {} jobs, {} workers, n={} ==", batch.len(), workers, n);
    let service = OffloadService::start(cfg)?;
    service.cache().clear()?; // guaranteed cold even across bench re-runs

    let t0 = Instant::now();
    let cold: Vec<_> = service
        .run_batch(&batch)
        .into_iter()
        .collect::<Result<Vec<_>, _>>()?;
    let cold_wall = t0.elapsed();
    let cold_stats = service.stats();
    println!("cold pass: {}", cold_stats.render());

    let t0 = Instant::now();
    let warm: Vec<_> = service
        .run_batch(&batch)
        .into_iter()
        .collect::<Result<Vec<_>, _>>()?;
    let warm_wall = t0.elapsed();

    // Cache contract: every warm job is a hit, and its bytes equal the
    // fresh serialization of the same (source, entry, DB) decision.
    let mut byte_identical = true;
    for (c, w) in cold.iter().zip(&warm) {
        assert!(w.from_cache, "warm pass must be served entirely from the cache");
        byte_identical &= c.report_json == w.report_json;
    }
    assert!(byte_identical, "cached decisions must be byte-identical to fresh ones");

    let jobs = batch.len() as f64;
    let cold_jps = jobs / cold_wall.as_secs_f64().max(1e-12);
    let warm_jps = jobs / warm_wall.as_secs_f64().max(1e-12);
    let gain = warm_jps / cold_jps.max(1e-12);

    let mut t = Table::new(&["pass", "wall", "jobs/sec", "cache"]);
    t.row(&[
        "cold (verify all)".into(),
        fmt_duration(cold_wall),
        format!("{cold_jps:.2}"),
        format!("{} misses", cold_stats.cache_misses),
    ]);
    t.row(&[
        "warm (replay)".into(),
        fmt_duration(warm_wall),
        format!("{warm_jps:.2}"),
        format!("{} entries", service.stats().cache_entries),
    ]);
    print!("{}", t.render());
    println!("warm/cold throughput: {gain:.1}x");

    let out = Json::obj(vec![
        ("bench", Json::str("service_throughput")),
        ("n", Json::num(n as f64)),
        ("jobs", Json::num(jobs)),
        ("workers", Json::num(workers as f64)),
        ("cold_secs", Json::num(cold_wall.as_secs_f64())),
        ("cold_jobs_per_sec", Json::num(cold_jps)),
        ("warm_secs", Json::num(warm_wall.as_secs_f64())),
        ("warm_jobs_per_sec", Json::num(warm_jps)),
        ("warm_over_cold", Json::num(gain)),
        ("cache_entries", Json::num(service.stats().cache_entries as f64)),
        ("byte_identical", Json::Bool(byte_identical)),
    ]);
    let bench_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_service.json");
    std::fs::write(&bench_path, json::to_string_pretty(&out))?;
    println!("recorded {}", bench_path.display());

    std::fs::remove_dir_all(&cache_dir).ok();
    // Wall-clock thesis — skipped in smoke mode, where timings on a noisy
    // shared runner prove nothing (the cache contract above still holds).
    if !smoke {
        assert!(
            gain >= 10.0,
            "warm cache must be >= 10x cold throughput (measured {gain:.1}x)"
        );
    }
    Ok(())
}
