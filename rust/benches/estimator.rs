//! Bench: analytic pre-arbitration estimation over the evaluation apps.
//!
//! For each app, the staged pipeline runs once in full; the same source
//! is then re-offloaded under pruning policies, gating three invariants:
//!
//! 1. `--prune-policy off` (the default) — the byte-identity gate: the
//!    report must serialize as v2 with no estimate section, and the
//!    decision must be completely invariant to the loaded device profile
//!    (an advisory estimate cannot influence an off-policy arbitration),
//!    which is exactly the pre-estimate behavior;
//! 2. `--prune-policy conservative:0.25` — the decision-agreement gate:
//!    pruning may only withhold predicted-hopeless patterns from
//!    measurement, so it must measure no more patterns than the full run
//!    and land on the identical final decision;
//! 3. the v4 estimate residue — per-block predicted-vs-measured error
//!    and its MAPE (arXiv:2004.09883's sizing accuracy), recorded per
//!    app for the trend line.
//!
//! Run: `cargo bench --bench estimator` (add `-- --test` for the CI
//! smoke mode: 1 rep).
//! Records: `BENCH_estimator.json` at the repo root.

use std::path::PathBuf;

use fbo::coordinator::{apps, Coordinator, ProfileRegistry, PrunePolicy};
use fbo::metrics::Table;
use fbo::patterndb::json::{self, Json};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test");
    let n = env_usize("FBO_N", 64);
    let reps = env_usize("FBO_REPS", if smoke { 1 } else { 3 });

    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut c = Coordinator::open(&artifacts)?;
    c.verify.reps = reps;

    println!("== analytic estimation: eval apps at n={n}, --target auto ==");
    let mut table = Table::new(&[
        "app",
        "full backend",
        "pruned backend",
        "patterns full",
        "patterns pruned",
        "mape",
    ]);
    let mut rows = Vec::new();
    let mut total_pruned = 0usize;
    let mut mape_sum = 0.0f64;
    let mut mape_count = 0usize;

    for (name, src) in apps::all(n) {
        let req = c.request(&src, "main");
        let verified = req.parse()?.discover(&req)?.reconcile(&req)?.verify(&req)?;

        // 1. Default off path: v2 bytes, no estimate residue.
        let full = verified.arbitrate(&req)?;
        let full_report = full.report();
        let full_json = fbo::coordinator::report_json::report_to_string(&full_report);
        assert!(
            full_json.contains("fbo-offload-report-v2"),
            "{name}: the default policy must emit v2 report bytes"
        );
        assert!(
            !full_json.contains("\"estimate\""),
            "{name}: the default policy must record no estimate section"
        );

        // Byte-identity gate: an off-policy arbitration is a *measurement*
        // decision, so the device profile must be unable to influence any
        // of it — same per-block backends, same overall backend, same
        // request times — which is precisely the pre-estimate behavior.
        let mut exotic = ProfileRegistry::builtin();
        exotic.active_gpu = "Tesla V100".to_string();
        let exotic_req = c.request(&src, "main").with_profiles(exotic);
        let full_exotic = verified.arbitrate(&exotic_req)?;
        assert_eq!(
            full.arbitration.backend, full_exotic.arbitration.backend,
            "{name}: off-policy decisions must be profile-independent"
        );
        assert_eq!(
            full.arbitration.blocks, full_exotic.arbitration.blocks,
            "{name}: off-policy per-block backends must be profile-independent"
        );

        // 2. Conservative pruning: full pipeline re-run so the estimate
        // actually shapes the verify plan.
        let mut pruning = Coordinator::open(&artifacts)?;
        pruning.verify.reps = reps;
        pruning.prune_policy = PrunePolicy::Conservative(0.25);
        let pruned = pruning.offload(&src, "main")?;
        assert!(
            pruned.outcome.tried.len() <= full_report.outcome.tried.len(),
            "{name}: pruning must never add measurements"
        );
        assert_eq!(
            pruned.outcome.best_enabled, full_report.outcome.best_enabled,
            "{name}: conservative pruning must keep the winning pattern"
        );
        assert_eq!(
            pruned.arbitration.backend, full_report.arbitration.backend,
            "{name}: conservative pruning must keep the arbitrated backend"
        );
        let saved =
            full_report.outcome.tried.len() - pruned.outcome.tried.len();
        total_pruned += saved;

        // 3. The v4 residue: predicted-vs-measured error per block.
        let residue = pruned
            .arbitration
            .estimate
            .as_ref()
            .expect("non-default policy must record the estimate residue");
        let pruned_json = fbo::coordinator::report_json::report_to_string(&pruned);
        assert!(
            pruned_json.contains("fbo-offload-report-v4"),
            "{name}: a non-default estimator config must emit the v4 report"
        );
        let mape = residue.mape;
        if let Some(m) = mape {
            assert!(m.is_finite() && m >= 0.0, "{name}: MAPE must be a finite ratio");
            mape_sum += m;
            mape_count += 1;
        }

        let fmt_mape = |v: Option<f64>| match v {
            Some(m) => format!("{:.1}%", m * 100.0),
            None => "-".to_string(),
        };
        table.row(&[
            name.clone(),
            full_report.arbitration.backend.as_str().to_string(),
            pruned.arbitration.backend.as_str().to_string(),
            full_report.outcome.tried.len().to_string(),
            pruned.outcome.tried.len().to_string(),
            fmt_mape(mape),
        ]);
        rows.push(Json::obj(vec![
            ("app", Json::str(&name)),
            ("full_backend", Json::str(full_report.arbitration.backend.as_str())),
            ("pruned_backend", Json::str(pruned.arbitration.backend.as_str())),
            ("full_patterns", Json::num(full_report.outcome.tried.len() as f64)),
            ("pruned_patterns", Json::num(pruned.outcome.tried.len() as f64)),
            ("patterns_saved", Json::num(saved as f64)),
            ("decision_identical", Json::Bool(true)),
            ("off_is_v2", Json::Bool(true)),
            ("mape", mape.map(Json::num).unwrap_or(Json::Null)),
            ("gpu_profile", Json::str(&residue.gpu_profile)),
            ("fpga_profile", Json::str(&residue.fpga_profile)),
        ]));
    }
    print!("{}", table.render());
    println!("conservative pruning saved {total_pruned} measured pattern(s) across the apps");

    let out = Json::obj(vec![
        ("bench", Json::str("estimator")),
        ("n", Json::num(n as f64)),
        ("reps", Json::num(reps as f64)),
        ("apps", Json::Arr(rows)),
        ("patterns_saved", Json::num(total_pruned as f64)),
        (
            "mape_mean",
            if mape_count > 0 {
                Json::num(mape_sum / mape_count as f64)
            } else {
                Json::Null
            },
        ),
    ]);
    let bench_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_estimator.json");
    std::fs::write(&bench_path, json::to_string_pretty(&out))?;
    println!("recorded {}", bench_path.display());
    Ok(())
}
