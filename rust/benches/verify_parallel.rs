//! Bench: serial vs pooled pattern-search verification (Step 3).
//!
//! The paper measures every offload pattern serially on the verification
//! machine; the per-stage latency counters show that this dominates
//! end-to-end wall time. The baseline and the phase-1 single-block
//! patterns are independent, so the pooled executor fans them across
//! sibling PJRT engines and pays the slowest pattern instead of the sum.
//! This bench runs both executors over the 3-block sensor-fusion app and
//! asserts the *decisions* are identical — the parallelism buys time,
//! never a different answer.
//!
//! Run: `cargo bench --bench verify_parallel` (add `-- --test` for the
//! CI smoke mode: 1 rep, no wall-clock assertion — timing on shared
//! runners is noise).
//! Records: `BENCH_verify.json` at the repo root.

use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use fbo::coordinator::{apps, Coordinator, OffloadReport};
use fbo::metrics::Table;
use fbo::patterndb::json::{self, Json};
use fbo::service::MeasurePool;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn pattern_labels(r: &OffloadReport) -> Vec<String> {
    r.outcome.tried.iter().map(|p| p.label.clone()).collect()
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--test");
    let n = env_usize("FBO_N", 64);
    let reps = env_usize("FBO_REPS", if smoke { 1 } else { 3 });
    let parallel = env_usize("FBO_VERIFY_PARALLEL", 4).max(2);
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let src = apps::sensor_fusion_app(n);

    println!(
        "== verify executors: sensor-fusion app (3 blocks) at n={n}, reps={reps}, \
         --verify-parallel {parallel} =="
    );

    // Serial: one engine, patterns back to back. Warm once so artifact
    // compiles (cached in the engine) are not billed to either side.
    let mut serial = Coordinator::open(&artifacts)?;
    serial.verify.reps = reps;
    let _ = serial.offload(&src, "main")?;
    let t0 = Instant::now();
    let serial_report = serial.offload(&src, "main")?;
    let serial_secs = t0.elapsed().as_secs_f64();

    // Pooled: local engine + (parallel - 1) measure-only siblings.
    let mut pooled = Coordinator::open(&artifacts)?;
    pooled.verify.reps = reps;
    let pool = MeasurePool::start(&artifacts, parallel - 1)?;
    pooled.executor = Some(Rc::new(pool.executor(pooled.engine.clone(), parallel)));
    let _ = pooled.offload(&src, "main")?;
    let t0 = Instant::now();
    let pooled_report = pooled.offload(&src, "main")?;
    let pooled_secs = t0.elapsed().as_secs_f64();

    // The determinism contract: identical decision regardless of executor.
    assert!(
        serial_report.outcome.tried.len() >= 4,
        "expected >=3 per-block patterns + combined, got {:?}",
        pattern_labels(&serial_report)
    );
    assert_eq!(
        serial_report.outcome.best_enabled, pooled_report.outcome.best_enabled,
        "serial and pooled searches must pick the same pattern"
    );
    assert_eq!(
        pattern_labels(&serial_report),
        pattern_labels(&pooled_report),
        "tried order must match"
    );

    let speedup = serial_secs / pooled_secs.max(1e-12);
    let mut table = Table::new(&["executor", "wall (s)", "patterns", "best speedup"]);
    for (name, secs, report) in [
        ("serial", serial_secs, &serial_report),
        ("pooled", pooled_secs, &pooled_report),
    ] {
        table.row(&[
            name.to_string(),
            format!("{secs:.3}"),
            report.outcome.tried.len().to_string(),
            format!("{:.1}", report.best_speedup()),
        ]);
    }
    print!("{}", table.render());
    println!("pooled vs serial verify wall: {speedup:.2}x");

    let out = Json::obj(vec![
        ("bench", Json::str("verify_parallel")),
        ("n", Json::num(n as f64)),
        ("reps", Json::num(reps as f64)),
        ("verify_parallel", Json::num(parallel as f64)),
        ("blocks", Json::num(3.0)),
        (
            "patterns",
            Json::Arr(pattern_labels(&serial_report).iter().map(Json::str).collect()),
        ),
        ("serial_secs", Json::num(serial_secs)),
        ("pooled_secs", Json::num(pooled_secs)),
        ("speedup", Json::num(speedup)),
        ("best_speedup", Json::num(serial_report.best_speedup())),
        (
            "decisions_identical",
            Json::Bool(serial_report.outcome.best_enabled == pooled_report.outcome.best_enabled),
        ),
    ]);
    let bench_path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_verify.json");
    std::fs::write(&bench_path, json::to_string_pretty(&out))?;
    println!("recorded {}", bench_path.display());

    // Wall-clock thesis — skipped in smoke mode, where 1-rep timings on a
    // noisy shared runner prove nothing.
    if !smoke {
        assert!(
            pooled_secs < serial_secs,
            "pooled verify ({pooled_secs:.3}s) must beat serial ({serial_secs:.3}s)"
        );
    }
    Ok(())
}
