//! Micro-bench: PJRT dispatch hot path (L3 -> artifact -> L3).
//!
//! Times per-call latency and effective bandwidth of each artifact with
//! inputs staged exactly as the host glue stages them (f64 interpreter
//! buffers -> f32 literals -> execute -> f32 -> f64 write-back is the
//! end-to-end cost a function-block call pays).
//!
//! Run: `cargo bench --bench runtime_dispatch`

use std::time::Instant;

use fbo::interp::{Slice, Value};
use fbo::metrics::Table;
use fbo::patterndb::PatternDb;
use fbo::runtime::Engine;
use fbo::transform::glue;

fn main() -> anyhow::Result<()> {
    let artifacts =
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = Engine::open(&artifacts)?;
    let db = PatternDb::builtin();

    let mut t = Table::new(&["artifact", "reps", "median/call", "MB moved/call", "GB/s"]);

    // Raw engine dispatch per artifact.
    for name in engine.artifact_names() {
        let meta = engine.meta(&name).unwrap().clone();
        let inputs: Vec<Vec<f32>> = meta
            .inputs
            .iter()
            .map(|s| {
                let mut v = vec![0.5f32; s.elems()];
                // Keep LU-ish inputs well-conditioned.
                let n = s.shape[0];
                if s.shape.len() == 2 && s.shape[0] == s.shape[1] {
                    for i in 0..n {
                        v[i * n + i] = n as f32;
                    }
                }
                v
            })
            .collect();
        engine.execute(&name, &inputs)?; // warm (compile)
        let reps = 20;
        let t0 = Instant::now();
        for _ in 0..reps {
            engine.execute(&name, &inputs)?;
        }
        let per = t0.elapsed() / reps;
        let bytes: usize = meta.inputs.iter().map(|s| s.elems() * 4).sum::<usize>()
            + meta.outputs.iter().map(|s| s.elems() * 4).sum::<usize>();
        t.row(&[
            name.clone(),
            reps.to_string(),
            format!("{:.2?}", per),
            format!("{:.2}", bytes as f64 / 1e6),
            format!("{:.2}", bytes as f64 / per.as_secs_f64() / 1e9),
        ]);
    }
    print!("{}", t.render());

    // Full glue path (what an interpreted call site pays).
    println!("\nhost-glue end-to-end (f64 slices -> artifact -> write-back):");
    let repl = &db.find_library("fft2d").unwrap().replacement;
    let f = glue::build_external(engine.clone(), repl)?;
    let n = 64usize;
    let re = Slice::zeros(&[n, n], false);
    let im = Slice::zeros(&[n, n], false);
    f(&[Value::Arr(re.clone()), Value::Arr(im.clone()), Value::Int(n as i64)])?; // warm
    let reps = 50;
    let t0 = Instant::now();
    for _ in 0..reps {
        f(&[Value::Arr(re.clone()), Value::Arr(im.clone()), Value::Int(n as i64)])?;
    }
    println!("  __fb_fft2d n=64: {:.2?}/call", t0.elapsed() / reps);
    Ok(())
}
