//! Micro-bench: front-end throughput (parse + analyze), L3 substrate.
//!
//! The coordinator's Step 1 must stay negligible next to the measured
//! verification trials; this bench tracks lines/second for parsing and
//! full analysis over a synthetic NR-style corpus.
//!
//! Run: `cargo bench --bench parser_throughput`

use std::time::Instant;

use fbo::metrics::Table;
use fbo::patterndb::corpus;
use fbo::{analysis, parser};

fn big_source(copies: usize) -> String {
    let mut src = String::new();
    for i in 0..copies {
        src.push_str(
            &corpus::NR_FFT2D
                .replace("four1", &format!("four1_{i}"))
                .replace("fft2d_cpu", &format!("fft2d_cpu_{i}")),
        );
        src.push_str(
            &corpus::NR_LUDCMP.replace("ludcmp_nopiv", &format!("ludcmp_{i}")),
        );
        src.push_str(&corpus::NR_MATMUL.replace("matmul_cpu", &format!("mm_{i}")));
    }
    src
}

fn main() -> anyhow::Result<()> {
    // Recursive-descent parsing of a very large unit wants stack room;
    // run the bench body on a thread with an explicit 64 MiB stack.
    std::thread::Builder::new()
        .stack_size(64 << 20)
        .spawn(run)?
        .join()
        .expect("bench thread")
}

fn run() -> anyhow::Result<()> {
    let mut t = Table::new(&["corpus", "lines", "parse", "analyze", "KLoC/s (parse)"]);
    for copies in [1usize, 8, 32] {
        let src = big_source(copies);
        let lines = src.lines().count();

        let t0 = Instant::now();
        let mut prog = None;
        for _ in 0..5 {
            prog = Some(parser::parse(&src)?);
        }
        let parse_t = t0.elapsed() / 5;

        let prog = prog.unwrap();
        let t0 = Instant::now();
        for _ in 0..5 {
            let _ = analysis::analyze(&prog);
        }
        let analyze_t = t0.elapsed() / 5;

        t.row(&[
            format!("{copies}x NR set"),
            lines.to_string(),
            format!("{:.2?}", parse_t),
            format!("{:.2?}", analyze_t),
            format!("{:.0}", lines as f64 / parse_t.as_secs_f64() / 1e3),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}
