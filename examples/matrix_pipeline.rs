//! Matrix-calculation pipeline + environment-adaptation Steps 4-7.
//!
//! Factor-then-solve (getrf + getrs analogs) through the PJRT artifacts,
//! verify the solve numerically, then run the paper's Steps 4-5: size the
//! deployment from the *measured* request time and place it under
//! latency/cost constraints; finally trigger the Step-7 reconfiguration
//! hook with a price change.
//!
//! ```bash
//! make artifacts && cargo run --release --example matrix_pipeline
//! ```

use std::path::Path;
use std::time::Instant;

use fbo::coordinator::flow;
use fbo::metrics::fmt_duration;
use fbo::runtime::Engine;

const N: usize = 256;
const NRHS: usize = 8;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open(Path::new("artifacts"))?;
    engine.artifact(&format!("lu_factor_n{N}"))?;
    engine.artifact(&format!("lu_solve_n{N}"))?;

    // Diagonally-dominant system.
    let mut a = vec![0f32; N * N];
    for i in 0..N {
        for j in 0..N {
            a[i * N + j] =
                0.3 * ((0.01 * (i * j + 1) as f32).sin()) + if i == j { N as f32 } else { 0.0 };
        }
    }
    let b: Vec<f32> = (0..N * NRHS).map(|i| ((i % 13) as f32) - 6.0).collect();

    // Factor.
    let t = Instant::now();
    let lu = engine.execute(&format!("lu_factor_n{N}"), &[a.clone()])?;
    let t_factor = t.elapsed();

    // Solve (one fused artifact: factor+solve, the getrs path).
    let t = Instant::now();
    let x = engine.execute(&format!("lu_solve_n{N}"), &[a.clone(), b.clone()])?;
    let t_solve = t.elapsed();

    // Verify: ||A x - b|| / ||b||.
    let xs = &x[0];
    let mut num = 0f64;
    let mut den = 0f64;
    for i in 0..N {
        for r in 0..NRHS {
            let mut s = 0f64;
            for k in 0..N {
                s += a[i * N + k] as f64 * xs[k * NRHS + r] as f64;
            }
            let d = s - b[i * NRHS + r] as f64;
            num += d * d;
            den += (b[i * NRHS + r] as f64).powi(2);
        }
    }
    let resid = (num / den).sqrt();
    println!(
        "LU {N}x{N}: factor {} (U11={:.3}), solve {NRHS} rhs {} (residual {:.2e})",
        fmt_duration(t_factor),
        lu[0][0],
        fmt_duration(t_solve),
        resid
    );
    anyhow::ensure!(resid < 1e-3, "solve residual too large");

    // Steps 4-5: size + place from the measured request time.
    let req = flow::Requirements {
        target_rps: 200.0,
        max_latency_ms: 20.0,
        budget_per_month: 8000.0,
        max_kwh_per_month: None,
    };
    let plan = flow::plan_resources(t_solve.as_secs_f64(), &req)?;
    println!(
        "Step 4: {} instance(s) ({:.0} rps each) for {} rps target",
        plan.instances, plan.rps_per_instance, req.target_rps
    );
    let locations = vec![
        flow::Location {
            name: "edge-gw".into(),
            gpus: 1,
            fpgas: 1,
            cost_per_hour: 0.9,
            fpga_cost_per_hour: 0.35,
            energy_cost_per_kwh: 0.30,
            latency_ms: 3.0,
        },
        flow::Location {
            name: "regional-dc".into(),
            gpus: 8,
            fpgas: 4,
            cost_per_hour: 0.5,
            fpga_cost_per_hour: 0.2,
            energy_cost_per_kwh: 0.12,
            latency_ms: 12.0,
        },
        flow::Location {
            name: "central-cloud".into(),
            gpus: 64,
            fpgas: 32,
            cost_per_hour: 0.3,
            fpga_cost_per_hour: 0.12,
            energy_cost_per_kwh: 0.08,
            latency_ms: 45.0,
        },
    ];
    let placement = flow::plan_placement(&plan, &req, &locations)?;
    println!("Step 5: deploy at {} (${:.0}/month)", placement.location, placement.monthly_cost);

    // Step 7: environment change — regional price hike.
    let mut changed = locations.clone();
    changed[1].cost_per_hour *= 1.4;
    match flow::replan_on_change(&plan, &req, &changed, &placement)? {
        Some(new_plan) => println!(
            "Step 7: reconfigured -> {} (${:.0}/month)",
            new_plan.location, new_plan.monthly_cost
        ),
        None => println!("Step 7: no reconfiguration needed"),
    }
    Ok(())
}
