//! IoT vibration-monitoring scenario (the paper's motivating workload).
//!
//! A gateway collects 64 windows of 256 vibration samples per machine and
//! must report per-band energies upstream. The FFT is the hot block: this
//! example serves a stream of frames through the offloaded batched-FFT
//! artifact (`fft1d_b64_n256` — the cuFFT plan-many analog) and reports
//! throughput + latency, then shows the same frames processed by the
//! interpreted CPU app for contrast.
//!
//! ```bash
//! make artifacts && cargo run --release --example iot_vibration
//! ```

use std::path::Path;
use std::time::Instant;

use fbo::coordinator::{apps, Coordinator};
use fbo::metrics::fmt_duration;
use fbo::runtime::Engine;

const WINDOWS: usize = 64;
const SAMPLES: usize = 256;
const FRAMES: usize = 50;

fn synth_frame(frame: usize) -> (Vec<f32>, Vec<f32>) {
    // A couple of machine tones + harmonics, drifting per frame.
    let mut re = Vec::with_capacity(WINDOWS * SAMPLES);
    for w in 0..WINDOWS {
        for s in 0..SAMPLES {
            let t = s as f32 / SAMPLES as f32;
            let f1 = 8.0 + (frame % 7) as f32;
            let f2 = 37.0;
            re.push(
                (std::f32::consts::TAU * f1 * t).sin()
                    + 0.4 * (std::f32::consts::TAU * f2 * t + w as f32 * 0.1).sin(),
            );
        }
    }
    (re, vec![0f32; WINDOWS * SAMPLES])
}

fn dominant_band(spec_re: &[f32], spec_im: &[f32]) -> usize {
    // Aggregate magnitude over windows, pick the strongest positive bin.
    let mut best = (0usize, 0f32);
    for bin in 1..SAMPLES / 2 {
        let mut e = 0f32;
        for w in 0..WINDOWS {
            let i = w * SAMPLES + bin;
            e += spec_re[i] * spec_re[i] + spec_im[i] * spec_im[i];
        }
        if e > best.1 {
            best = (bin, e);
        }
    }
    best.0
}

fn main() -> anyhow::Result<()> {
    let engine = Engine::open(Path::new("artifacts"))?;
    // Warm the executable (cuFFT "plan creation").
    engine.artifact("fft1d_b64_n256")?;

    println!("-- serving {FRAMES} frames through the offloaded batched FFT --");
    let t0 = Instant::now();
    let mut lat_min = f64::MAX;
    let mut lat_max: f64 = 0.0;
    let mut bands = Vec::new();
    for frame in 0..FRAMES {
        let (re, im) = synth_frame(frame);
        let t = Instant::now();
        let out = engine.execute("fft1d_b64_n256", &[re, im])?;
        let lat = t.elapsed().as_secs_f64();
        lat_min = lat_min.min(lat);
        lat_max = lat_max.max(lat);
        bands.push(dominant_band(&out[0], &out[1]));
    }
    let total = t0.elapsed();
    println!(
        "  {} frames in {} -> {:.1} frames/s, latency {:.2}..{:.2} ms",
        FRAMES,
        fmt_duration(total),
        FRAMES as f64 / total.as_secs_f64(),
        lat_min * 1e3,
        lat_max * 1e3
    );
    println!("  dominant bands (first 10 frames): {:?}", &bands[..10]);
    let st = engine.stats.borrow();
    println!(
        "  engine: {} executions, {:.1} MB in, {:.1} MB out",
        st.executions,
        st.bytes_in as f64 / 1e6,
        st.bytes_out as f64 / 1e6
    );
    drop(st);

    println!("-- contrast: one frame on the interpreted CPU app (2-D FFT path) --");
    let coordinator = Coordinator::open(Path::new("artifacts"))?;
    let report = coordinator.offload(&apps::fft_app_lib(64), "main")?;
    println!(
        "  app all-CPU {} vs offloaded {} ({}x)",
        fmt_duration(report.outcome.baseline.median),
        fmt_duration(report.outcome.best_time.median),
        fbo::metrics::fmt_speedup(report.best_speedup())
    );
    Ok(())
}
