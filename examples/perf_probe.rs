//! Perf probe: interpreter throughput on the evaluation apps
//! (median-of-5; the verification environment's hot path).
//!
//! ```bash
//! cargo run --release --example perf_probe
//! ```

use std::time::Instant;

use fbo::coordinator::{apps, Coordinator};
use fbo::interp::Interp;
use fbo::parser;

fn main() -> anyhow::Result<()> {
    let c = Coordinator::open(std::path::Path::new("artifacts"))?;
    for (label, src) in [
        ("fft_lib_64", apps::fft_app_lib(64)),
        ("lu_lib_64", apps::lu_app_lib(64)),
        ("stencil_96", apps::stencil_app(96)),
    ] {
        let prog = parser::parse(&src)?;
        let linked = c.link_cpu_libraries(&prog)?;
        let mut m = Interp::new(&linked)?;
        let mut times = Vec::new();
        for _ in 0..5 {
            m.reset_run_state()?;
            let t0 = Instant::now();
            m.run("main", &[])?;
            times.push(t0.elapsed());
        }
        times.sort();
        let med = times[2];
        println!(
            "{label}: median {med:?} ({} steps, {:.1} Msteps/s)",
            m.stats.steps,
            m.stats.steps as f64 / med.as_secs_f64() / 1e6
        );
    }
    Ok(())
}
