//! Trace one full offload end to end: run the sensor-fusion app through
//! the pipeline under a [`fbo::telemetry::TraceObserver`], then export
//! the trace twice — canonical JSONL (the `--trace-out` wire format) and
//! Chrome `trace_event` JSON you can open directly in Perfetto.
//!
//! ```bash
//! make artifacts && cargo run --release --example trace_offload
//! ```
//!
//! Load the printed `.trace.json` at <https://ui.perfetto.dev> (or
//! `chrome://tracing`): the seven pipeline stages render as spans on one
//! track, with every pattern measurement, power score, and arbitration
//! verdict as instant markers inside them.

use std::sync::Arc;

use fbo::coordinator::{apps, Coordinator};
use fbo::telemetry::{TraceEvent, TraceObserver, TraceRecorder, DEFAULT_RING_CAPACITY};

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let out_dir =
        std::env::temp_dir().join(format!("fbo-trace-example-{}", std::process::id()));
    std::fs::create_dir_all(&out_dir)?;
    let jsonl_path = out_dir.join("offload.trace.jsonl");
    let chrome_path = out_dir.join("offload.trace.json");

    let mut c = Coordinator::open(&artifacts)?;
    c.verify.reps = 1;
    let src = apps::sensor_fusion_app(64);

    // Every record is mirrored to the JSONL sink as it happens — exactly
    // what `fbo offload --trace-out FILE` does.
    let recorder = Arc::new(TraceRecorder::with_sink(DEFAULT_RING_CAPACITY, &jsonl_path)?);
    let obs = Arc::new(TraceObserver::begin(&recorder, "main"));
    let report = c.request(&src, "main").with_observer(obs.clone()).run()?;
    obs.complete(false, true);
    recorder.flush()?;

    println!(
        "offloaded sensor_fusion: best speedup {} via {}",
        fbo::metrics::fmt_speedup(report.best_speedup()),
        report.backend().as_str(),
    );

    let records = recorder.records();
    let spans = records
        .iter()
        .filter(|r| matches!(r.event, TraceEvent::StageCompleted { .. }))
        .count();
    let patterns = records
        .iter()
        .filter(|r| matches!(r.event, TraceEvent::PatternMeasured { .. }))
        .count();
    let verdicts = records
        .iter()
        .filter(|r| matches!(r.event, TraceEvent::ArbitrationVerdict { .. }))
        .count();
    println!(
        "trace {}: {} records ({spans} stage spans, {patterns} pattern measurements, \
         {verdicts} verdicts)",
        obs.trace_id(),
        records.len(),
    );

    std::fs::write(&chrome_path, recorder.chrome_trace())?;
    println!("JSONL trace:  {}", jsonl_path.display());
    println!("Chrome trace: {}", chrome_path.display());
    println!("open the Chrome trace at https://ui.perfetto.dev (or chrome://tracing)");
    Ok(())
}
