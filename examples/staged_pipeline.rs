//! Inspect-and-resume through the staged pipeline API.
//!
//! The expensive part of the paper's flow is Step 3 — every candidate
//! pattern is actually measured in the verification environment. The
//! staged API makes that cost resumable: run the pipeline through
//! [`Verified`] once, keep the artifact (a plain serializable value),
//! then arbitrate it under different backend policies without ever
//! re-measuring.
//!
//! ```bash
//! make artifacts && cargo run --release --example staged_pipeline
//! ```

use fbo::coordinator::{apps, BackendPolicy, Coordinator, PowerPolicy, Verified};

fn main() -> anyhow::Result<()> {
    let mut coordinator = Coordinator::open(std::path::Path::new("artifacts"))?;
    coordinator.verify.reps = 1;
    let source = apps::lu_app_lib(64);

    // Stages 1-3: parse -> discover -> reconcile -> verify. Each artifact
    // is a value; inspect whatever you need along the way.
    let request = coordinator.request(&source, "main");
    let parsed = request.parse()?;
    let discovered = parsed.discover(&request)?;
    println!(
        "discovered {} candidate block(s) from {} external callee(s)",
        discovered.candidates.len(),
        discovered.external_callees.len()
    );
    // The analytic estimate sits between reconciliation and measurement:
    // every block is scored against the active device profiles before a
    // single rep runs. Under the default `--prune-policy off` it is
    // purely advisory — the measurements below are untouched by it.
    let estimated = discovered.reconcile(&request)?.estimate(&request)?;
    for block in &estimated.estimates.blocks {
        println!(
            "estimate: {} -> predicted {} at {:.2e}s (cpu {:.2e}s)",
            block.label,
            block.predicted_backend().as_str(),
            block.predicted_secs(),
            block.cpu_secs
        );
    }
    let verified = estimated.verify(&request)?;
    println!(
        "verified: {} pattern(s) measured, best speedup {:.1} (wall {:?})",
        verified.outcome.tried.len(),
        verified.outcome.best_speedup,
        verified.wall
    );

    // The Verified artifact serializes — ship it to another process, put
    // it in a cache, or just keep the string around...
    let saved = verified.to_json_string();

    // ...then resume it under `--target gpu`: arbitration re-runs against
    // the *same* measurements, no re-verification.
    let gpu_request = coordinator.request(&source, "main").with_target(BackendPolicy::Gpu);
    let gpu = Verified::from_json_str(&saved)?.arbitrate(&gpu_request)?;
    println!(
        "--target gpu  -> backend {} ({:.2} simulated toolchain hours)",
        gpu.arbitration.backend.as_str(),
        gpu.arbitration.simulated_hours
    );

    // Mutate the backend policy and resume the same artifact again: a
    // different Arbitrated outcome from identical measurements.
    let fpga_request = coordinator.request(&source, "main").with_target(BackendPolicy::Fpga);
    let fpga = Verified::from_json_str(&saved)?.arbitrate(&fpga_request)?;
    println!(
        "--target fpga -> backend {} ({:.2} simulated toolchain hours)",
        fpga.arbitration.backend.as_str(),
        fpga.arbitration.simulated_hours
    );

    assert_ne!(
        gpu.arbitration.backend, fpga.arbitration.backend,
        "the resumed artifact must arbitrate differently under a different target"
    );
    assert_eq!(
        gpu.verified.outcome.best_speedup, fpga.verified.outcome.best_speedup,
        "both decisions rest on the same cached measurements"
    );

    // The power stage resumes the same way: score the saved measurements
    // under perf-per-watt, inspect the modeled energy, then arbitrate.
    let ppw_request = coordinator
        .request(&source, "main")
        .with_power_policy(PowerPolicy::PerfPerWatt);
    let scored = Verified::from_json_str(&saved)?.power_score(&ppw_request)?;
    for block in &scored.scores.blocks {
        if let Some(gpu_energy) = &block.gpu {
            println!(
                "power-score: {} -> {:.2} mJ/run, efficiency {:.1}x vs CPU",
                block.label,
                gpu_energy.energy_j * 1e3,
                gpu_energy.efficiency
            );
        }
    }
    let powered = scored.arbitrate(&ppw_request)?;
    println!(
        "--power-policy perf-per-watt -> backend {}",
        powered.arbitration.backend.as_str()
    );
    assert!(powered.arbitration.power.is_some(), "v3 report records the energy residue");

    println!("same measurements, three deployments - verification ran once.");
    Ok(())
}
