//! Batch offloading through the service layer: the paper's expensive
//! measured verification runs once per (source, entry, DB) and is then
//! served from the persistent decision cache.
//!
//! ```bash
//! make artifacts && cargo run --release --example batch_offload
//! ```
//!
//! Pass 1 verifies every evaluation app (all cache misses), pass 2 replays
//! the same batch (all hits, no measurement), and pass 3 proves the cache
//! survives a service restart.

use fbo::coordinator::apps;
use fbo::service::{OffloadService, ServiceConfig};

fn config(cache_dir: &std::path::Path) -> ServiceConfig {
    let artifacts =
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut cfg = ServiceConfig::new(artifacts);
    cfg.cache_dir = Some(cache_dir.to_path_buf());
    cfg.workers = 2;
    cfg.verify.reps = 1;
    cfg
}

fn main() -> anyhow::Result<()> {
    let n = 64;
    let (names, batch): (Vec<String>, Vec<(String, String)>) = apps::all(n)
        .into_iter()
        .map(|(name, src)| (name, (src, "main".to_string())))
        .unzip();

    let cache_dir =
        std::env::temp_dir().join(format!("fbo-batch-example-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cache_dir);

    let service = OffloadService::start(config(&cache_dir))?;

    for pass in 1..=2 {
        println!("== pass {pass} ==");
        let t0 = std::time::Instant::now();
        for (name, result) in names.iter().zip(service.run_batch(&batch)) {
            let done = result?;
            println!(
                "  {name:<22} speedup {:>6}  {}  {}",
                fbo::metrics::fmt_speedup(done.report.best_speedup()),
                fbo::metrics::fmt_duration(done.wall),
                if done.from_cache { "cache hit" } else { "verified (cache miss)" },
            );
        }
        println!("  pass wall: {}", fbo::metrics::fmt_duration(t0.elapsed()));
        println!("  {}", service.stats().render());
    }
    let first_stats = service.stats();
    assert_eq!(first_stats.cache_misses, batch.len() as u64, "pass 1 must verify every app");
    assert_eq!(first_stats.cache_hits, batch.len() as u64, "pass 2 must be all cache hits");
    service.shutdown();

    // Restart: decisions were persisted as JSON next to the artifacts dir
    // (redirected to a temp dir for this example), so a fresh service
    // replays them without re-verifying.
    println!("== pass 3 (after service restart) ==");
    let service = OffloadService::start(config(&cache_dir))?;
    for (name, result) in names.iter().zip(service.run_batch(&batch)) {
        let done = result?;
        assert!(done.from_cache, "{name} must be served from the persisted cache");
        println!(
            "  {name:<22} served from disk cache in {}",
            fbo::metrics::fmt_duration(done.wall)
        );
    }
    println!("  {}", service.stats().render());

    std::fs::remove_dir_all(&cache_dir).ok();
    Ok(())
}
