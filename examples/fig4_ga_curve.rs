//! Fig. 4 regeneration: GA generations vs best speedup for the Fourier-
//! transform application under *loop* offloading (the prior work [33]).
//!
//! The paper's figure shows the per-generation best of the GA search
//! climbing past 5x over ~20 generations on the 2048-point FFT app. This
//! driver runs the same search on our verification environment and prints
//! the series (an ASCII sparkline plus the table the bench also emits).
//!
//! ```bash
//! make artifacts && cargo run --release --example fig4_ga_curve [n] [gens]
//! ```

use fbo::coordinator::{apps, loop_offload, Coordinator};
use fbo::ga::GaConfig;
use fbo::metrics::Table;
use fbo::parser;

fn main() -> anyhow::Result<()> {
    let mut argv = std::env::args().skip(1);
    let n: usize = argv.next().map(|s| s.parse()).transpose()?.unwrap_or(64);
    let gens: usize = argv.next().map(|s| s.parse()).transpose()?.unwrap_or(10);

    let coordinator = Coordinator::open(std::path::Path::new("artifacts"))?;
    let prog = parser::parse(&apps::fft_app_lib(n))?;
    let linked = coordinator.link_cpu_libraries(&prog)?;

    let cfg = GaConfig { population: 12, generations: gens, ..Default::default() };
    eprintln!("running GA loop-offload search on the FFT app (n={n}, {gens} generations)...");
    let r = loop_offload::ga_loop_search(&linked, "main", &cfg, 1, u64::MAX)?;

    println!("parallelizable loops (genes): {}", r.loop_ids.len());
    for (i, l) in r.loop_labels.iter().enumerate() {
        println!("  gene[{i}] {l}");
    }

    let mut table = Table::new(&["generation", "best speedup", "mean speedup", "measured trials"]);
    let max = r
        .ga
        .history
        .iter()
        .map(|g| g.best_speedup)
        .fold(1.0f64, f64::max);
    for g in &r.ga.history {
        table.row(&[
            g.generation.to_string(),
            format!("{:.2}", g.best_speedup),
            format!("{:.2}", g.mean_speedup),
            g.trials.to_string(),
        ]);
    }
    print!("{}", table.render());

    println!("\nbest-of-generation (paper Fig. 4 shape — rises then plateaus):");
    for g in &r.ga.history {
        let bar = "#".repeat(((g.best_speedup / max) * 40.0) as usize);
        println!("  gen {:>2} |{bar:<40}| {:.2}x", g.generation, g.best_speedup);
    }
    println!(
        "\nfinal: {:.2}x over all-CPU with gene {:?} ({} verification trials)",
        r.ga.best_speedup(),
        r.ga.best_gene,
        r.ga.trials
    );
    Ok(())
}
