//! Quickstart: offload one application's function blocks in ~10 lines.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use fbo::coordinator::{apps, Coordinator};

fn main() -> anyhow::Result<()> {
    // The coordinator = pattern DB + PJRT engine + verification settings.
    let coordinator = Coordinator::open(std::path::Path::new("artifacts"))?;

    // A CPU application that calls the NR-style `matmul` library.
    let source = apps::matmul_app(64);

    // Steps 1-3: analyze, match blocks against the DB, reconcile
    // interfaces, and measure every offload pattern in the verification
    // environment. The fastest correct pattern wins.
    let report = coordinator.offload(&source, "main")?;

    print!("{}", coordinator.render_report(&report));
    println!("--- winning transformed source ---");
    print!("{}", report.transformed_source);
    Ok(())
}
