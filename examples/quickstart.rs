//! Quickstart: offload one application's function blocks in ~10 lines.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use fbo::coordinator::{apps, Coordinator};

fn main() -> anyhow::Result<()> {
    // The coordinator = pattern DB + PJRT engine + verification settings.
    let coordinator = Coordinator::open(std::path::Path::new("artifacts"))?;

    // A CPU application that calls the NR-style `matmul` library.
    let source = apps::matmul_app(64);

    // Build a request and run every stage: analyze, match blocks against
    // the DB, reconcile interfaces, measure every offload pattern in the
    // verification environment, arbitrate the backend. The fastest
    // correct pattern wins. (See examples/staged_pipeline.rs for driving
    // the stages one by one.)
    let report = coordinator.request(&source, "main").run()?;

    print!("{}", coordinator.render_report(&report));
    println!("--- winning transformed source ---");
    print!("{}", report.transformed_source);
    Ok(())
}
