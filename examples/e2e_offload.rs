//! End-to-end driver: the paper's full evaluation on a real small workload.
//!
//! Runs the complete three-layer system — rust coordinator → PJRT-compiled
//! JAX/Pallas artifacts — over all four evaluation applications (FFT and LU,
//! each in library-call and copied-code discovery variants), plus the GA
//! loop-offload baseline of the prior work, and prints the Fig. 5-shaped
//! headline table: all-CPU vs loop offloading vs function-block offloading.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_offload [n]
//! ```
//!
//! `n` defaults to 64 (CI-scale). Use 256 for the headline run recorded in
//! EXPERIMENTS.md (the paper used 2048 on real hardware; see DESIGN.md
//! "Substitutions").

use std::path::Path;

use fbo::coordinator::{apps, loop_offload, Coordinator};
use fbo::ga::GaConfig;
use fbo::metrics::{fmt_duration, fmt_speedup, Table};
use fbo::parser;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(64);
    let mut coordinator = Coordinator::open(Path::new("artifacts"))?;
    coordinator.verify.reps = if n >= 256 { 1 } else { 3 };

    let cases = [
        ("Fourier transform (lib call)", apps::fft_app_lib(n)),
        ("Fourier transform (copied)", apps::fft_app_copy(n)),
        ("Matrix calculation (lib call)", apps::lu_app_lib(n)),
        ("Matrix calculation (copied)", apps::lu_app_copy(n)),
    ];

    let mut table = Table::new(&[
        "application",
        "all-CPU",
        "loop offload [33]",
        "function blocks (ours)",
        "found via",
    ]);

    for (label, src) in &cases {
        eprintln!("== {label} (n={n}) ==");

        // Function-block pipeline (Steps 1-3), through the staged API.
        let report = coordinator.request(src, "main").run()?;
        eprint!("{}", coordinator.render_report(&report));

        // GA loop-offload baseline on the same (linked) program.
        let prog = parser::parse(src)?;
        let linked = coordinator.link_cpu_libraries(&prog)?;
        let ga_cfg = GaConfig {
            population: 10,
            generations: if n >= 256 { 6 } else { 8 },
            ..Default::default()
        };
        let ga = loop_offload::ga_loop_search(&linked, "main", &ga_cfg, 1, u64::MAX)?;
        eprintln!(
            "GA loop offload: {} genes, best {}x after {} trials",
            ga.loop_ids.len(),
            fmt_speedup(ga.ga.best_speedup()),
            ga.ga.trials
        );

        let via = report
            .blocks
            .iter()
            .filter(|b| b.accepted())
            .map(|b| match &b.via {
                fbo::coordinator::DiscoveryPath::LibraryMatch { library } => {
                    format!("DB name match ({library})")
                }
                fbo::coordinator::DiscoveryPath::Similarity { block, score } => {
                    format!("similarity ({block}, {score:.2})")
                }
            })
            .collect::<Vec<_>>()
            .join("; ");

        table.row(&[
            label.to_string(),
            fmt_duration(report.outcome.baseline.median),
            format!("{}x", fmt_speedup(ga.ga.best_speedup())),
            format!("{}x", fmt_speedup(report.best_speedup())),
            via,
        ]);
    }

    println!("\n=== headline (Fig. 5 shape: speedup vs all-CPU) ===");
    print!("{}", table.render());
    println!(
        "\npaper (2048, Quadro P4000): FFT 5.4x -> 730x; matrix 38x -> 130000x.\n\
         shape check: function blocks >> loop offload on both apps, matrix gap larger."
    );
    Ok(())
}
