# Repo-level entry points.
#
#   make artifacts   lower the JAX/Pallas function blocks to HLO text
#                    (writes rust/artifacts/*.hlo.txt + manifest.json)
#   make test        tier-1 verification
#   make bench       throughput + paper-figure benches

.PHONY: artifacts test bench

artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts

test:
	cargo build --release && cargo test -q

bench:
	cargo bench --bench service_throughput
	cargo bench --bench search_time
