"""AOT compile path: lower each function-block graph to HLO **text**.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust ``xla`` crate) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Run once via ``make artifacts``. Emits:
    artifacts/<name>.hlo.txt     one per (op, n)
    artifacts/manifest.json      shapes/dtypes/signatures for the rust side

Usage: python -m compile.aot [--out-dir ../artifacts] [--sizes 64,256]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Default artifact grid. 256 is the headline size (paper used 2048; see
# DESIGN.md "Substitutions" — interpreted-CPU LU at 2048 is infeasible, the
# speedup *shape* is preserved at 256). 64 is the test/CI size.
DEFAULT_SIZES = (64, 256)
SOLVE_RHS = 8  # columns in the lu_solve right-hand side


def spec(shape: tuple[int, ...]) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_specs(sizes=DEFAULT_SIZES):
    """(name, fn, arg_specs, description) for every artifact we ship."""
    out = []
    for n in sizes:
        out.append(
            (
                f"fft2d_n{n}",
                model.fft2d,
                (spec((n, n)), spec((n, n))),
                f"2-D complex FFT, {n}x{n} grid, split re/im planes (cuFFT analog)",
            )
        )
        out.append(
            (
                f"lu_factor_n{n}",
                model.lu_factor,
                (spec((n, n)),),
                f"packed blocked no-pivot LU of {n}x{n} (cuSOLVER getrf analog)",
            )
        )
        out.append(
            (
                f"matmul_n{n}",
                model.matmul,
                (spec((n, n)), spec((n, n))),
                f"dense {n}x{n} matmul (cuBLAS gemm analog)",
            )
        )
        out.append(
            (
                f"lu_solve_n{n}",
                model.lu_solve,
                (spec((n, n)), spec((n, SOLVE_RHS))),
                f"solve A X = B, A {n}x{n}, B {n}x{SOLVE_RHS} (cuSOLVER getrs analog)",
            )
        )
    # Batched 1-D FFT for the IoT vibration example: 64 windows of 256.
    out.append(
        (
            "fft1d_b64_n256",
            model.fft1d_batch,
            (spec((64, 256)), spec((64, 256))),
            "batched 1-D complex FFT, 64 windows x 256 samples (cuFFT plan-many analog)",
        )
    )
    return out


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text, return_tuple=True.

    CRITICAL: print with ``print_large_constants=True``. The default HLO
    printer elides big constants as ``constant({...})`` and the XLA 0.5.1
    text parser silently materializes those as ZEROS — the DFT/twiddle
    tables of the FFT artifact would vanish (discovered the hard way; see
    EXPERIMENTS.md "Gotchas").
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # New-jax metadata attributes (source_end_line etc.) are unknown to the
    # 0.5.1 text parser — strip metadata entirely.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_one(fn, arg_specs) -> tuple[str, list[dict], list[dict]]:
    """Lower ``fn`` and return (hlo_text, input sig, output sig)."""
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    ins = [{"shape": list(s.shape), "dtype": "f32"} for s in arg_specs]
    out_avals = jax.eval_shape(fn, *arg_specs)
    if not isinstance(out_avals, tuple):
        out_avals = (out_avals,)
    outs = [{"shape": list(o.shape), "dtype": "f32"} for o in out_avals]
    return text, ins, outs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--sizes",
        default=",".join(str(s) for s in DEFAULT_SIZES),
        help="comma-separated square sizes to lower",
    )
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(","))
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "artifacts": []}
    for name, fn, arg_specs, desc in artifact_specs(sizes):
        text, ins, outs = lower_one(fn, arg_specs)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "description": desc,
                "inputs": ins,
                "outputs": outs,
            }
        )
        print(f"  {name}: {len(text)} chars, in={ins}, out={outs}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
