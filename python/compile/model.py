"""L2: JAX compute graphs for the offloadable function blocks.

Each public function here is one **function block** in the paper's sense —
the unit the code-pattern DB maps a CPU library call (or similarity-matched
code copy) onto. They call the L1 Pallas kernels and are AOT-lowered by
``aot.py`` into one self-contained HLO-text artifact per (op, n), which the
rust runtime loads through PJRT. Python never runs at request time.

Complex data crosses the PJRT boundary as split real/imag f32 planes (the
``xla`` crate speaks f32 literals natively; cuFFT's C2C interface is
likewise an array of (re, im) pairs).
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from .kernels import fft as fft_k
from .kernels import lu as lu_k
from .kernels import matmul as mm_k


def fft2d(re: jnp.ndarray, im: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """2-D complex FFT (cuFFT analog). (n,n)+(n,n) f32 -> (n,n)+(n,n)."""
    return fft_k.fft2d(re, im)


def fft1d_batch(re: jnp.ndarray, im: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched 1-D complex FFT over rows (cuFFT plan-many analog)."""
    return fft_k.fft1d(re, im)


def lu_factor(a: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Packed blocked LU (cuSOLVER getrf analog). (n,n) f32 -> (n,n)."""
    return (lu_k.lu_factor(a),)


def lu_solve(a: jnp.ndarray, rhs: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Solve A X = RHS (cuSOLVER getrs analog)."""
    return (lu_k.lu_solve(a, rhs),)


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Dense matmul (cuBLAS gemm analog)."""
    return (mm_k.matmul(a, b),)


def dot_blocks() -> dict[str, Callable]:
    """Name -> graph map used by aot.py and the python tests."""
    return {
        "fft2d": fft2d,
        "fft1d_batch": fft1d_batch,
        "lu_factor": lu_factor,
        "lu_solve": lu_solve,
        "matmul": matmul,
    }
