"""L1/L2 kernel: four-step FFT — the cuFFT-analog function block.

Hardware adaptation (DESIGN.md section "Hardware-Adaptation"): cuFFT's speed
on GPU comes from mapping butterflies onto warps with staged shared-memory
transposes. The TPU-shaped re-expression of the same insight is Bailey's
**four-step (transpose) FFT**: factor n = n1*n2 and express the transform as

    1. n2 batched DFTs of size n1        -> dense matmul against W(n1)
    2. twiddle multiply by w_n^(j2*k1)   -> elementwise (VPU)
    3. n1 batched DFTs of size n2        -> dense matmul against W(n2)
    4. transpose                          -> layout change

so *all* O(n log n)-ish work lands on the MXU systolic array as dense
matmuls (the Pallas ``matmul`` kernel), exactly as cuFFT lands it on warp
MMA. The DFT/twiddle matrices are compile-time constants baked into the AOT
artifact — the runtime only feeds data, like calling into cuFFT's plan.

Derivation (j = j1*n2 + j2, k = k1 + n1*k2, w = exp(-2*pi*i/n)):
    X[k1 + n1*k2] = sum_{j2} w^(j2*k1) W(n2)[j2,k2] * (sum_{j1} W(n1)[j1,k1] x[j1*n2+j2])
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .matmul import cmatmul


def dft_matrix(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Real/imag planes of the dense DFT matrix W[j,k] = exp(-2*pi*i*j*k/n)."""
    j = np.arange(n)
    ang = -2.0 * np.pi * np.outer(j, j) / n
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


def twiddle(n1: int, n2: int) -> tuple[np.ndarray, np.ndarray]:
    """Twiddle planes T[j2,k1] = exp(-2*pi*i*j2*k1/(n1*n2))."""
    n = n1 * n2
    ang = -2.0 * np.pi * np.outer(np.arange(n2), np.arange(n1)) / n
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


def split_factors(n: int) -> tuple[int, int]:
    """Balanced n = n1 * n2 factorization (n1 <= n2), preferring squares."""
    n1 = int(np.sqrt(n))
    while n1 > 1 and n % n1 != 0:
        n1 -= 1
    return n1, n // n1


def fft1d(re: jnp.ndarray, im: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched 1-D FFT over the last axis via the four-step algorithm.

    ``re``/``im``: (batch, n) f32 planes. Returns (batch, n) planes.
    """
    b, n = re.shape
    n1, n2 = split_factors(n)
    w1r, w1i = dft_matrix(n1)
    w2r, w2i = dft_matrix(n2)
    tr, ti = twiddle(n1, n2)

    # Step 1 — inner DFTs over j1: view rows as (n1, n2) matrices, transpose
    # to (n2, n1), flatten the batch into rows and hit the MXU:
    #   A[b, j2, k1] = sum_j1 M[b, j1, j2] * W1[j1, k1]
    m_re = re.reshape(b, n1, n2).transpose(0, 2, 1).reshape(b * n2, n1)
    m_im = im.reshape(b, n1, n2).transpose(0, 2, 1).reshape(b * n2, n1)
    a_re, a_im = cmatmul(m_re, m_im, jnp.asarray(w1r), jnp.asarray(w1i))

    # Step 2 — twiddle (elementwise, VPU): B[b, j2, k1] = A * T[j2, k1]
    a_re = a_re.reshape(b, n2, n1)
    a_im = a_im.reshape(b, n2, n1)
    t_re = jnp.asarray(tr)[None, :, :]
    t_im = jnp.asarray(ti)[None, :, :]
    b_re = a_re * t_re - a_im * t_im
    b_im = a_re * t_im + a_im * t_re

    # Step 3 — outer DFTs over j2:
    #   C[b, k1, k2] = sum_j2 B[b, j2, k1] * W2[j2, k2]
    b_re2 = b_re.transpose(0, 2, 1).reshape(b * n1, n2)
    b_im2 = b_im.transpose(0, 2, 1).reshape(b * n1, n2)
    c_re, c_im = cmatmul(b_re2, b_im2, jnp.asarray(w2r), jnp.asarray(w2i))

    # Step 4 — transpose to the natural output order k = k1 + n1*k2.
    out_re = c_re.reshape(b, n1, n2).transpose(0, 2, 1).reshape(b, n)
    out_im = c_im.reshape(b, n1, n2).transpose(0, 2, 1).reshape(b, n)
    return out_re, out_im


def fft2d(re: jnp.ndarray, im: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """2-D FFT of an (n, m) grid: row transforms, then column transforms."""
    # Rows.
    r_re, r_im = fft1d(re, im)
    # Columns: transpose, row-transform, transpose back.
    c_re, c_im = fft1d(r_re.T, r_im.T)
    return c_re.T, c_im.T
