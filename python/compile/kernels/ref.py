"""Pure-jnp reference oracles for the Pallas kernels.

Every L1 kernel in this package has a reference implementation here written
with plain ``jax.numpy`` (no Pallas, no custom tiling). pytest compares the
kernels against these oracles; the rust integration tests compare the PJRT
artifacts against values computed from the same formulas.
"""

from __future__ import annotations

import jax.numpy as jnp
import jax.scipy.linalg as jsl


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain dense matmul, f32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def cmatmul_ref(
    ar: jnp.ndarray, ai: jnp.ndarray, br: jnp.ndarray, bi: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Complex matmul on split real/imag operands (4-real-matmul formula)."""
    re = jnp.matmul(ar, br) - jnp.matmul(ai, bi)
    im = jnp.matmul(ar, bi) + jnp.matmul(ai, br)
    return re, im


def fft2d_ref(re: jnp.ndarray, im: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """2-D FFT oracle via jnp.fft on a complex64 view."""
    x = re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64)
    y = jnp.fft.fft2(x)
    return jnp.real(y).astype(jnp.float32), jnp.imag(y).astype(jnp.float32)


def fft1d_ref(re: jnp.ndarray, im: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched 1-D FFT oracle over the last axis."""
    x = re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64)
    y = jnp.fft.fft(x, axis=-1)
    return jnp.real(y).astype(jnp.float32), jnp.imag(y).astype(jnp.float32)


def lu_ref(a: jnp.ndarray) -> jnp.ndarray:
    """Packed LU (no pivoting) oracle.

    Returns the compact LU matrix: U on and above the diagonal, unit-lower L
    strictly below. Inputs are assumed diagonally dominant (see DESIGN.md —
    the paper's workload uses well-conditioned matrices so the no-pivot
    factorization matches cuSOLVER's getrf modulo the permutation).
    """
    n = a.shape[0]
    lu = a.astype(jnp.float32)
    for i in range(n):
        piv = lu[i, i]
        col = lu[:, i] / piv
        row_idx = jnp.arange(n)
        l_col = jnp.where(row_idx > i, col, 0.0)
        u_row = jnp.where(row_idx >= i, lu[i, :], 0.0)
        lu = lu - l_col[:, None] * u_row[None, :]
        lu = lu.at[:, i].set(jnp.where(row_idx > i, l_col, lu[:, i]))
    return lu


def lu_unpack(lu: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Split a packed LU matrix into (L, U) with unit diagonal on L."""
    l = jnp.tril(lu, -1) + jnp.eye(lu.shape[0], dtype=lu.dtype)
    u = jnp.triu(lu)
    return l, u


def lu_residual(a: jnp.ndarray, lu: jnp.ndarray) -> jnp.ndarray:
    """Relative reconstruction error ||L@U - A|| / ||A||."""
    l, u = lu_unpack(lu)
    return jnp.linalg.norm(l @ u - a) / jnp.linalg.norm(a)


def lu_solve_ref(lu: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Solve A x = b from the packed no-pivot LU."""
    l, u = lu_unpack(lu)
    y = jsl.solve_triangular(l, b, lower=True, unit_diagonal=True)
    return jsl.solve_triangular(u, y, lower=False)
