"""L1/L2 kernel: blocked right-looking LU — the cuSOLVER-analog block.

Hardware adaptation: cuSOLVER getrf is a blocked right-looking LU — a thin
panel is factored with scalar math, then the large trailing submatrix is
updated with one GEMM per panel (where ~all FLOPs live). That structure is
already MXU-shaped: the trailing update ``A22 -= L21 @ U12`` runs on the
Pallas matmul kernel (MXU), the panel factorization is a ``fori_loop`` of
masked rank-1 updates (VPU work on real TPU), and the triangular solves are
small constant-trip loops over the panel width.

No pivoting: the paper's workload (and our rust workload generator) feeds
diagonally-dominant matrices, for which LU without pivoting is backward
stable. Documented in DESIGN.md "Substitutions".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .matmul import matmul

DEFAULT_BLOCK = 32


def _panel_lu(panel: jnp.ndarray) -> jnp.ndarray:
    """Unblocked in-place LU of a (b, b) panel via masked rank-1 updates."""
    b = panel.shape[0]
    idx = jnp.arange(b)

    def body(i, p):
        piv = p[i, i]
        l_col = jnp.where(idx > i, p[:, i] / piv, 0.0)
        u_row = jnp.where(idx >= i, p[i, :], 0.0)
        p = p - l_col[:, None] * u_row[None, :]
        return p.at[:, i].set(jnp.where(idx > i, l_col, p[:, i]))

    return lax.fori_loop(0, b, body, panel.astype(jnp.float32))


def _solve_unit_lower(l11: jnp.ndarray, a12: jnp.ndarray) -> jnp.ndarray:
    """U12 from L11 @ U12 = A12, L11 unit-lower (forward substitution)."""
    b = l11.shape[0]
    idx = jnp.arange(b)

    def body(i, u):
        # row_i of U12 = A12_i - sum_{j<i} L[i,j] U[j,:]; L masked to j < i.
        l_row = jnp.where(idx < i, l11[i, :], 0.0)
        return u.at[i, :].set(u[i, :] - l_row @ u)

    return lax.fori_loop(0, b, body, a12.astype(jnp.float32))


def _solve_upper_right(u11: jnp.ndarray, a21: jnp.ndarray) -> jnp.ndarray:
    """L21 from L21 @ U11 = A21 (column-wise forward substitution)."""
    b = u11.shape[0]
    idx = jnp.arange(b)

    def body(j, l):
        u_col = jnp.where(idx < j, u11[:, j], 0.0)
        col = (l[:, j] - l @ u_col) / u11[j, j]
        return l.at[:, j].set(col)

    return lax.fori_loop(0, b, body, a21.astype(jnp.float32))


def _lu_block_view(lu: jnp.ndarray, panel: jnp.ndarray, k: int, b: int,
                   n: int) -> jnp.ndarray:
    return lax.dynamic_update_slice(lu, panel, (k, k))


@functools.partial(jax.jit, static_argnames=("block",))
def lu_factor(a: jnp.ndarray, *, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Packed no-pivot LU of a square matrix, blocked right-looking.

    Returns the compact LU: U on/above the diagonal, unit-lower L strictly
    below — the same packing cuSOLVER getrf uses.
    """
    n = a.shape[0]
    assert a.shape == (n, n), f"square matrix required, got {a.shape}"
    b = min(block, n)
    while n % b != 0:
        b -= 1
    lu = a.astype(jnp.float32)
    for k in range(0, n, b):  # static trace-time loop: offsets are constants
        a11 = lax.slice(lu, (k, k), (k + b, k + b))
        p11 = _panel_lu(a11)
        lu = lax.dynamic_update_slice(lu, p11, (k, k))
        rest = n - k - b
        if rest == 0:
            break
        a12 = lax.slice(lu, (k, k + b), (k + b, n))
        a21 = lax.slice(lu, (k + b, k), (n, k + b))
        u12 = _solve_unit_lower(p11, a12)
        l21 = _solve_upper_right(p11, a21)
        lu = lax.dynamic_update_slice(lu, u12, (k, k + b))
        lu = lax.dynamic_update_slice(lu, l21, (k + b, k))
        # Trailing update — the MXU hot spot: A22 -= L21 @ U12.
        a22 = lax.slice(lu, (k + b, k + b), (n, n))
        upd = matmul(l21, u12)
        lu = lax.dynamic_update_slice(lu, a22 - upd, (k + b, k + b))
    return lu


@functools.partial(jax.jit, static_argnames=("block",))
def lu_solve(a: jnp.ndarray, rhs: jnp.ndarray, *,
             block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Solve A X = RHS via the blocked LU (forward + back substitution)."""
    n = a.shape[0]
    lu = lu_factor(a, block=block)
    idx = jnp.arange(n)

    def fwd(i, y):
        l_row = jnp.where(idx < i, lu[i, :], 0.0)
        return y.at[i, :].set(y[i, :] - l_row @ y)

    y = lax.fori_loop(0, n, fwd, rhs.astype(jnp.float32))

    def bwd(step, x):
        i = n - 1 - step
        u_row = jnp.where(idx > i, lu[i, :], 0.0)
        return x.at[i, :].set((x[i, :] - u_row @ x) / lu[i, i])

    return lax.fori_loop(0, n, bwd, y)
