"""L1 Pallas kernel: tiled matmul (the cuBLAS-analog function block).

TPU adaptation of the paper's replacement target (cuBLAS GEMM): instead of
CUDA threadblocks staging tiles through shared memory, BlockSpec expresses
the HBM->VMEM schedule and each grid step feeds one (bm, bn) output tile to
the MXU, accumulating over the k-grid axis in the output ref.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is validated (and AOT-shipped) through the
interpreter lowering; the BlockSpec structure is what real-TPU performance
is estimated from (DESIGN.md / EXPERIMENTS.md section "Perf").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-shaped tile. The MXU is a 128x128 systolic array; (128, 128)
# output tiles with a 128-deep reduction step keep it fully fed.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One grid step: accumulate x_tile @ y_tile into the output tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is <= ``want`` (keeps grids exact)."""
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> jnp.ndarray:
    """``x @ y`` with MXU-tiled Pallas. Shapes must tile exactly."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def cmatmul(
    ar: jnp.ndarray,
    ai: jnp.ndarray,
    br: jnp.ndarray,
    bi: jnp.ndarray,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Complex matmul on split real/imag planes via 3 real Pallas matmuls.

    Karatsuba-style (Gauss) trick: t1 = ar@br, t2 = ai@bi,
    t3 = (ar+ai)@(br+bi); re = t1 - t2, im = t3 - t1 - t2.
    One fewer MXU pass than the naive 4-matmul form — this is the §Perf L1
    optimization for the FFT artifact (see EXPERIMENTS.md).
    """
    t1 = matmul(ar, br, bm=bm, bn=bn, bk=bk)
    t2 = matmul(ai, bi, bm=bm, bn=bn, bk=bk)
    t3 = matmul(ar + ai, br + bi, bm=bm, bn=bn, bk=bk)
    return t1 - t2, t3 - t1 - t2


def vmem_bytes(m: int, n: int, k: int, bm: int = DEFAULT_BM,
               bn: int = DEFAULT_BN, bk: int = DEFAULT_BK) -> int:
    """Estimated VMEM residency of one grid step (f32)."""
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    return 4 * (bm * bk + bk * bn + bm * bn)
