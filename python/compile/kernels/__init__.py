"""L1 Pallas kernels: cuFFT / cuSOLVER / cuBLAS analogs for the offload DB."""
