"""Kernel-vs-oracle correctness: the core L1 signal.

Each Pallas kernel (interpret=True) is compared against the pure-jnp
oracles in ``compile.kernels.ref`` with ``assert_allclose``. Hypothesis
sweeps shapes and block configurations per the repo test policy.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

# The property sweeps need hypothesis (installed in the CI python job);
# without it this module skips instead of failing collection, so a bare
# `pytest python/` still runs the AOT tests on a minimal environment.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import fft as fft_k
from compile.kernels import lu as lu_k
from compile.kernels import matmul as mm_k
from compile.kernels import ref

RNG = np.random.default_rng(20200207)


def randf(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


def diag_dominant(n: int) -> np.ndarray:
    a = RNG.standard_normal((n, n)).astype(np.float32)
    return a + n * np.eye(n, dtype=np.float32)


# ---------------------------------------------------------------- matmul


class TestMatmul:
    def test_square(self):
        a, b = randf(128, 128), randf(128, 128)
        np.testing.assert_allclose(
            mm_k.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4
        )

    def test_rectangular(self):
        a, b = randf(256, 64), randf(64, 192)
        np.testing.assert_allclose(
            mm_k.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4
        )

    def test_tiny(self):
        a, b = randf(2, 3), randf(3, 4)
        np.testing.assert_allclose(
            mm_k.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4
        )

    def test_identity(self):
        a = randf(64, 64)
        eye = np.eye(64, dtype=np.float32)
        np.testing.assert_allclose(mm_k.matmul(a, eye), a, rtol=1e-5, atol=1e-5)

    def test_zeros(self):
        a = randf(32, 32)
        z = np.zeros((32, 32), np.float32)
        np.testing.assert_allclose(mm_k.matmul(a, z), z, atol=0)

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from([8, 16, 48, 96, 128, 160]),
        k=st.sampled_from([8, 32, 64, 96, 128]),
        n=st.sampled_from([8, 16, 64, 128, 192]),
        seed=st.integers(0, 2**16),
    )
    def test_shape_sweep(self, m, k, n, seed):
        r = np.random.default_rng(seed)
        a = r.standard_normal((m, k)).astype(np.float32)
        b = r.standard_normal((k, n)).astype(np.float32)
        np.testing.assert_allclose(
            mm_k.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-3, atol=1e-3
        )

    @settings(max_examples=10, deadline=None)
    @given(
        bm=st.sampled_from([16, 32, 64, 128]),
        bn=st.sampled_from([16, 64, 128]),
        bk=st.sampled_from([16, 32, 128]),
    )
    def test_block_config_sweep(self, bm, bn, bk):
        """All legal BlockSpec tilings must agree with the oracle."""
        a, b = randf(128, 128), randf(128, 128)
        np.testing.assert_allclose(
            mm_k.matmul(a, b, bm=bm, bn=bn, bk=bk),
            ref.matmul_ref(a, b),
            rtol=1e-3,
            atol=1e-3,
        )

    def test_block_picker_divides(self):
        for dim in (1, 2, 7, 96, 100, 128, 256, 300):
            for want in (1, 16, 128, 999):
                blk = mm_k._pick_block(dim, want)
                assert dim % blk == 0 and 1 <= blk <= dim

    def test_vmem_estimate_within_budget(self):
        # Default tiles must fit comfortably in 16 MiB VMEM.
        assert mm_k.vmem_bytes(2048, 2048, 2048) <= 16 * 2**20


class TestCMatmul:
    def test_matches_four_matmul_formula(self):
        ar, ai = randf(96, 64), randf(96, 64)
        br, bi = randf(64, 80), randf(64, 80)
        gr, gi = mm_k.cmatmul(ar, ai, br, bi)
        er, ei = ref.cmatmul_ref(ar, ai, br, bi)
        np.testing.assert_allclose(gr, er, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(gi, ei, rtol=1e-3, atol=1e-3)

    def test_real_only_inputs(self):
        ar = randf(32, 32)
        z = np.zeros_like(ar)
        br = randf(32, 32)
        gr, gi = mm_k.cmatmul(ar, z, br, z)
        np.testing.assert_allclose(gr, ref.matmul_ref(ar, br), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gi, np.zeros_like(ar), atol=1e-4)


# ---------------------------------------------------------------- fft


class TestFFT:
    @pytest.mark.parametrize("n", [4, 16, 64, 256])
    def test_fft1d_matches_oracle(self, n):
        re, im = randf(8, n), randf(8, n)
        gr, gi = fft_k.fft1d(re, im)
        er, ei = ref.fft1d_ref(re, im)
        np.testing.assert_allclose(gr, er, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(gi, ei, rtol=1e-3, atol=1e-3)

    @pytest.mark.parametrize("n", [16, 64, 128])
    def test_fft2d_matches_oracle(self, n):
        re, im = randf(n, n), randf(n, n)
        gr, gi = fft_k.fft2d(re, im)
        er, ei = ref.fft2d_ref(re, im)
        np.testing.assert_allclose(gr, er, rtol=1e-3, atol=2e-3)
        np.testing.assert_allclose(gi, ei, rtol=1e-3, atol=2e-3)

    def test_fft_of_impulse_is_flat(self):
        n = 64
        re = np.zeros((1, n), np.float32)
        re[0, 0] = 1.0
        im = np.zeros_like(re)
        gr, gi = fft_k.fft1d(re, im)
        np.testing.assert_allclose(gr, np.ones((1, n)), atol=1e-4)
        np.testing.assert_allclose(gi, np.zeros((1, n)), atol=1e-4)

    def test_fft_of_constant_is_impulse(self):
        n = 64
        re = np.ones((1, n), np.float32)
        im = np.zeros_like(re)
        gr, _ = fft_k.fft1d(re, im)
        assert abs(gr[0, 0] - n) < 1e-3
        np.testing.assert_allclose(gr[0, 1:], np.zeros(n - 1), atol=1e-3)

    def test_parseval(self):
        """Energy preservation: sum|X|^2 = n * sum|x|^2."""
        n = 128
        re, im = randf(4, n), randf(4, n)
        gr, gi = fft_k.fft1d(re, im)
        e_time = np.sum(re**2 + im**2, axis=1)
        e_freq = np.sum(np.asarray(gr) ** 2 + np.asarray(gi) ** 2, axis=1)
        np.testing.assert_allclose(e_freq, n * e_time, rtol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.sampled_from([4, 8, 12, 16, 36, 64, 100, 144, 256]),
        batch=st.sampled_from([1, 3, 8]),
        seed=st.integers(0, 2**16),
    )
    def test_fft1d_shape_sweep(self, n, batch, seed):
        r = np.random.default_rng(seed)
        re = r.standard_normal((batch, n)).astype(np.float32)
        im = r.standard_normal((batch, n)).astype(np.float32)
        gr, gi = fft_k.fft1d(re, im)
        er, ei = ref.fft1d_ref(re, im)
        np.testing.assert_allclose(gr, er, rtol=1e-3, atol=2e-3)
        np.testing.assert_allclose(gi, ei, rtol=1e-3, atol=2e-3)

    def test_split_factors(self):
        for n in (4, 16, 64, 100, 256, 2048):
            n1, n2 = fft_k.split_factors(n)
            assert n1 * n2 == n and n1 <= n2

    def test_dft_matrix_unitary_scaled(self):
        wr, wi = fft_k.dft_matrix(16)
        w = wr + 1j * wi
        np.testing.assert_allclose(
            w @ w.conj().T, 16 * np.eye(16), atol=1e-4
        )


# ---------------------------------------------------------------- lu


class TestLU:
    @pytest.mark.parametrize("n", [8, 32, 64, 128])
    def test_reconstruction(self, n):
        a = diag_dominant(n)
        packed = lu_k.lu_factor(a)
        assert float(ref.lu_residual(a, packed)) < 1e-5

    @pytest.mark.parametrize("n", [16, 64])
    def test_matches_unblocked_oracle(self, n):
        a = diag_dominant(n)
        np.testing.assert_allclose(
            lu_k.lu_factor(a), ref.lu_ref(a), rtol=1e-3, atol=1e-3
        )

    def test_identity_factors_to_identity(self):
        eye = np.eye(32, dtype=np.float32)
        np.testing.assert_allclose(lu_k.lu_factor(eye), eye, atol=1e-6)

    def test_block_size_one_equals_unblocked(self):
        a = diag_dominant(16)
        np.testing.assert_allclose(
            lu_k.lu_factor(a, block=1), ref.lu_ref(a), rtol=1e-3, atol=1e-3
        )

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.sampled_from([8, 24, 48, 64, 96]),
        block=st.sampled_from([1, 4, 8, 16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_block_sweep(self, n, block, seed):
        r = np.random.default_rng(seed)
        a = r.standard_normal((n, n)).astype(np.float32) + n * np.eye(
            n, dtype=np.float32
        )
        packed = lu_k.lu_factor(a, block=block)
        assert float(ref.lu_residual(a, packed)) < 1e-4

    def test_solve(self):
        n = 64
        a = diag_dominant(n)
        rhs = randf(n, 8)
        x = lu_k.lu_solve(a, rhs)
        resid = np.linalg.norm(a @ np.asarray(x) - rhs) / np.linalg.norm(rhs)
        assert resid < 1e-5

    def test_solve_identity(self):
        eye = np.eye(16, dtype=np.float32)
        rhs = randf(16, 4)
        np.testing.assert_allclose(lu_k.lu_solve(eye, rhs), rhs, atol=1e-6)

    def test_lu_solve_matches_ref_solver(self):
        n = 32
        a = diag_dominant(n)
        rhs = randf(n, 4)
        packed = ref.lu_ref(a)
        np.testing.assert_allclose(
            lu_k.lu_solve(a, rhs),
            ref.lu_solve_ref(packed, rhs),
            rtol=1e-3,
            atol=1e-3,
        )
