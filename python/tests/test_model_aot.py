"""L2 model shapes + AOT lowering sanity.

Checks that every function-block graph lowers to HLO text that (a) is
non-trivial, (b) declares the right entry signature, and (c) the manifest
generator agrees with ``jax.eval_shape``.
"""

from __future__ import annotations

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import ref


class TestModelShapes:
    def test_fft2d_shapes(self):
        out = jax.eval_shape(
            model.fft2d, aot.spec((64, 64)), aot.spec((64, 64))
        )
        assert tuple(o.shape for o in out) == ((64, 64), (64, 64))

    def test_lu_factor_shape(self):
        (out,) = jax.eval_shape(model.lu_factor, aot.spec((64, 64)))
        assert out.shape == (64, 64)

    def test_lu_solve_shape(self):
        (out,) = jax.eval_shape(
            model.lu_solve, aot.spec((64, 64)), aot.spec((64, 8))
        )
        assert out.shape == (64, 8)

    def test_matmul_shape(self):
        (out,) = jax.eval_shape(
            model.matmul, aot.spec((64, 32)), aot.spec((32, 16))
        )
        assert out.shape == (64, 16)

    def test_block_map_complete(self):
        assert set(model.dot_blocks()) == {
            "fft2d",
            "fft1d_batch",
            "lu_factor",
            "lu_solve",
            "matmul",
        }

    def test_model_values_match_oracles(self):
        r = np.random.default_rng(7)
        re = r.standard_normal((16, 16)).astype(np.float32)
        im = r.standard_normal((16, 16)).astype(np.float32)
        gr, gi = model.fft2d(re, im)
        er, ei = ref.fft2d_ref(re, im)
        np.testing.assert_allclose(gr, er, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(gi, ei, rtol=1e-3, atol=1e-3)


class TestAOT:
    def test_artifact_specs_cover_all_sizes(self):
        specs = aot.artifact_specs((16, 32))
        names = [s[0] for s in specs]
        for n in (16, 32):
            assert f"fft2d_n{n}" in names
            assert f"lu_factor_n{n}" in names
            assert f"matmul_n{n}" in names
            assert f"lu_solve_n{n}" in names

    def test_lower_one_produces_hlo_text(self):
        text, ins, outs = aot.lower_one(
            model.matmul, (aot.spec((16, 16)), aot.spec((16, 16)))
        )
        assert "HloModule" in text
        assert "f32[16,16]" in text
        assert ins == [
            {"shape": [16, 16], "dtype": "f32"},
            {"shape": [16, 16], "dtype": "f32"},
        ]
        assert outs == [{"shape": [16, 16], "dtype": "f32"}]

    def test_lowered_fft_has_tuple_root(self):
        text, _, outs = aot.lower_one(
            model.fft2d, (aot.spec((16, 16)), aot.spec((16, 16)))
        )
        # return_tuple=True: root of entry computation is a tuple.
        assert "tuple(" in text.replace(" ", "") or "tuple " in text
        assert len(outs) == 2

    def test_main_writes_manifest(self, tmp_path, monkeypatch):
        out = str(tmp_path / "arts")
        monkeypatch.setattr(
            "sys.argv", ["aot", "--out-dir", out, "--sizes", "16"]
        )
        aot.main()
        with open(os.path.join(out, "manifest.json")) as f:
            man = json.load(f)
        assert man["format"] == "hlo-text"
        names = {a["name"] for a in man["artifacts"]}
        assert "fft2d_n16" in names and "lu_factor_n16" in names
        for a in man["artifacts"]:
            assert os.path.exists(os.path.join(out, a["file"]))

    def test_hlo_text_is_parseable_header(self):
        """Text must start with an HloModule line the xla crate can parse."""
        text, _, _ = aot.lower_one(model.lu_factor, (aot.spec((16, 16)),))
        assert text.lstrip().startswith("HloModule")
