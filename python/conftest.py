"""Make the in-repo ``compile`` package importable regardless of where
pytest is invoked from: the CI python job runs ``python -m pytest
python/`` from the repo root, where ``python/`` itself is not on
``sys.path``."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
